"""L2/AOT tests: model shapes, variant separation, HLO-text export."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export, to_hlo_text
from compile.model import (
    CLASSES_SENTIMENT,
    CLASSES_TOPIC,
    VARIANTS,
    example_tokens,
    make_weights,
    model_fn,
)
from compile.kernels.classifier import BATCH, TOKENS


def tok_batch(seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(1, 100, size=(BATCH, TOKENS), dtype=np.int32)
    return jnp.asarray(t)


def test_model_output_shapes():
    for name, (classes, seed) in VARIANTS.items():
        fn = model_fn(classes, seed)
        (logits,) = fn(tok_batch())
        assert logits.shape == (BATCH, classes), name
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_variants_differ():
    (a,) = model_fn(*VARIANTS["classifier"])(tok_batch())
    (b,) = model_fn(*VARIANTS["sentiment"])(tok_batch())
    assert a.shape[1] == CLASSES_TOPIC
    assert b.shape[1] == CLASSES_SENTIMENT


def test_weights_deterministic_per_seed():
    w1 = make_weights(CLASSES_TOPIC, 11)
    w2 = make_weights(CLASSES_TOPIC, 11)
    for a, b in zip(w1, w2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    w3 = make_weights(CLASSES_TOPIC, 12)
    assert not np.array_equal(np.asarray(w1[0]), np.asarray(w3[0]))


def test_hlo_text_lowering_roundtrip():
    fn = model_fn(*VARIANTS["sentiment"])
    lowered = jax.jit(fn).lower(example_tokens())
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # Tokens enter as a single ENTRY parameter (weights are baked
    # constants); subcomputations have their own parameter lists, so
    # restrict the check to the entry computation.
    entry = text[text.index("ENTRY"):]
    assert "parameter(0)" in entry
    assert "parameter(1)" not in entry
    # Large weight constants must be fully printed, not elided.
    assert "constant({...})" not in text


def test_export_writes_artifacts(tmp_path):
    path = export("sentiment", str(tmp_path))
    assert os.path.exists(path)
    with open(path) as f:
        head = f.read(200)
    assert "HloModule" in head


def test_example_tokens_matches_rust_constants():
    spec = example_tokens()
    assert spec.shape == (BATCH, TOKENS)
    assert spec.dtype == jnp.int32

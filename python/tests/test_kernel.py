"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

Fixed-shape checks plus hypothesis sweeps over token values, padding
patterns, and weight scales. The kernel's batch dimension is gridded in
BLOCK_B tiles, so batch sizes are multiples of BLOCK_B.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.classifier import (
    BATCH,
    BLOCK_B,
    TOKENS,
    VOCAB,
    classifier_fwd,
)
from compile.kernels.ref import ref_fwd
from compile.model import CLASSES_TOPIC, make_weights

ATOL = 1e-4
RTOL = 1e-4


def rand_tokens(rng, batch, pad_prob=0.3):
    tok = rng.integers(1, VOCAB, size=(batch, TOKENS), dtype=np.int32)
    mask = rng.random((batch, TOKENS)) < pad_prob
    tok[mask] = 0
    # Keep at least one real token per row so pooling is nontrivial.
    tok[:, 0] = np.maximum(tok[:, 0], 1)
    return jnp.asarray(tok)


@pytest.fixture(scope="module")
def weights():
    return make_weights(CLASSES_TOPIC, seed=11)


def test_kernel_matches_ref_fixed(weights):
    rng = np.random.default_rng(0)
    tok = rand_tokens(rng, BATCH)
    got = classifier_fwd(tok, *weights, classes=CLASSES_TOPIC)
    want = ref_fwd(tok, *weights)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_kernel_all_padding_rows_allowed(weights):
    # Rows of pure padding (id 0 everywhere except forced [:,0]=1 off):
    tok = jnp.zeros((BATCH, TOKENS), jnp.int32)
    got = classifier_fwd(tok, *weights, classes=CLASSES_TOPIC)
    want = ref_fwd(tok, *weights)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)
    assert np.all(np.isfinite(np.asarray(got)))


def test_kernel_single_token(weights):
    tok = np.zeros((BATCH, TOKENS), np.int32)
    tok[:, 0] = np.arange(1, BATCH + 1)
    got = classifier_fwd(jnp.asarray(tok), *weights, classes=CLASSES_TOPIC)
    want = ref_fwd(jnp.asarray(tok), *weights)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_kernel_deterministic(weights):
    rng = np.random.default_rng(1)
    tok = rand_tokens(rng, BATCH)
    a = classifier_fwd(tok, *weights, classes=CLASSES_TOPIC)
    b = classifier_fwd(tok, *weights, classes=CLASSES_TOPIC)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_row_independence(weights):
    # Blocked execution must not leak across rows: permuting the batch
    # permutes the logits identically.
    rng = np.random.default_rng(2)
    tok = np.asarray(rand_tokens(rng, BATCH))
    perm = rng.permutation(BATCH)
    out = np.asarray(classifier_fwd(jnp.asarray(tok), *weights, classes=CLASSES_TOPIC))
    out_p = np.asarray(
        classifier_fwd(jnp.asarray(tok[perm]), *weights, classes=CLASSES_TOPIC)
    )
    np.testing.assert_allclose(out[perm], out_p, atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    pad_prob=st.floats(0.0, 0.95),
)
def test_kernel_matches_ref_hypothesis(weights, seed, blocks, pad_prob):
    rng = np.random.default_rng(seed)
    tok = rand_tokens(rng, blocks * BLOCK_B, pad_prob)
    got = classifier_fwd(tok, *weights, classes=CLASSES_TOPIC)
    want = ref_fwd(tok, *weights)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 10.0))
def test_kernel_weight_scale_sweep(seed, scale):
    # Numerical agreement holds across weight magnitudes.
    emb, w1, b1, w2, b2 = make_weights(CLASSES_TOPIC, seed=7)
    emb, w1, w2 = emb * scale, w1 * scale, w2 * scale
    rng = np.random.default_rng(seed)
    tok = rand_tokens(rng, BLOCK_B)
    got = classifier_fwd(tok, emb, w1, b1, w2, b2, classes=CLASSES_TOPIC)
    want = ref_fwd(tok, emb, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, atol=1e-3 * max(scale, 1.0), rtol=1e-3)


def test_extreme_token_ids(weights):
    # Boundary vocab ids must not read out of bounds.
    tok = np.zeros((BLOCK_B, TOKENS), np.int32)
    tok[:, 0] = VOCAB - 1
    tok[:, 1] = 1
    got = classifier_fwd(jnp.asarray(tok), *weights, classes=CLASSES_TOPIC)
    want = ref_fwd(jnp.asarray(tok), *weights)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_relu_actually_clips(weights):
    # Sanity on the nonlinearity: with hugely negative b1 the hidden
    # layer is all-zero → logits equal b2 exactly.
    emb, w1, _, w2, b2 = weights
    b1_neg = jnp.full((1, w1.shape[1]), -1e9, jnp.float32)
    rng = np.random.default_rng(3)
    tok = rand_tokens(rng, BLOCK_B)
    got = classifier_fwd(tok, emb, w1, b1_neg, w2, b2, classes=CLASSES_TOPIC)
    np.testing.assert_allclose(got, np.broadcast_to(b2, got.shape), atol=ATOL)

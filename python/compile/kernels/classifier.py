"""L1 — the Pallas kernel: fused embedding-sum + 2-layer MLP classifier.

This is the compute hot-spot of the workflows' ML operators (the
`SentimentAnalysis` / topic-`ML` operators of the paper's W3 and Ch. 4
workflows). The fusion is the point: the reference implementation is a
chain of gather → reduce → matmul → relu → matmul, each a separate HBM
round-trip on real hardware; the kernel keeps the pooled activation and
both weight matrices resident in VMEM and runs the whole pipeline per
batch-block.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid over the batch dimension; each program handles a (BLOCK_B, T)
    tile of token ids;
  * the embedding table is processed via one-hot matmul (MXU-friendly;
    gather is a poor fit for the systolic array);
  * weights (V·D + D·H + H·C floats ≈ 2.2 MB at default sizes) stay in
    VMEM across grid steps (constant index_map);
  * matmul shapes are multiples of 8/128 where it matters.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO, which XLA compiles to
fast native code (this is an AOT path, not an eval-loop).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Model dimensions — must match rust/src/operators/ml_infer.rs.
BATCH = 32
TOKENS = 16
VOCAB = 4096
EMBED = 128
HIDDEN = 256

# Batch tile per pallas program.
BLOCK_B = 8


def classifier_kernel(tok_ref, emb_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One grid step: classify a (BLOCK_B, TOKENS) tile of token ids.

    tok_ref: int32[BLOCK_B, TOKENS]   token ids (0 = padding)
    emb_ref: f32[VOCAB, EMBED]        embedding table (VMEM-resident)
    w1_ref:  f32[EMBED, HIDDEN]
    b1_ref:  f32[1, HIDDEN]
    w2_ref:  f32[HIDDEN, C]
    b2_ref:  f32[1, C]
    out_ref: f32[BLOCK_B, C]          logits
    """
    tok = tok_ref[...]                                  # (B, T) int32
    # One-hot over the vocab, masking padding (id 0 contributes zero).
    # MXU path: (B*T, V) @ (V, D) instead of a gather.
    mask = (tok > 0).astype(jnp.float32)                # (B, T)
    onehot = jax.nn.one_hot(tok, VOCAB, dtype=jnp.float32)  # (B, T, V)
    onehot = onehot * mask[..., None]
    flat = onehot.reshape(-1, VOCAB)                    # (B*T, V)
    emb = flat @ emb_ref[...]                           # (B*T, D)
    emb = emb.reshape(tok.shape[0], tok.shape[1], EMBED)
    # Mean-pool over real tokens.
    denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = emb.sum(axis=1) / denom                    # (B, D)
    # 2-layer MLP, fused in-register.
    h = jnp.maximum(pooled @ w1_ref[...] + b1_ref[...], 0.0)
    out_ref[...] = h @ w2_ref[...] + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("classes",))
def classifier_fwd(tokens, emb, w1, b1, w2, b2, *, classes):
    """Full-batch forward pass via the Pallas kernel (L2 calls this)."""
    n_blocks = tokens.shape[0] // BLOCK_B
    return pl.pallas_call(
        classifier_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, TOKENS), lambda i: (i, 0)),
            # Weights: constant index_map — stay resident across steps.
            pl.BlockSpec((VOCAB, EMBED), lambda i: (0, 0)),
            pl.BlockSpec((EMBED, HIDDEN), lambda i: (0, 0)),
            pl.BlockSpec((1, HIDDEN), lambda i: (0, 0)),
            pl.BlockSpec((HIDDEN, classes), lambda i: (0, 0)),
            pl.BlockSpec((1, classes), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, classes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens.shape[0], classes), jnp.float32),
        interpret=True,
    )(tokens, emb, w1, b1, w2, b2)

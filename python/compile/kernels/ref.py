"""Pure-jnp oracle for the classifier kernel.

This is the unfused reference chain (gather → mask → mean-pool → MLP).
pytest asserts `classifier_fwd(...) ≈ ref_fwd(...)` over random inputs
and hypothesis-driven shape/value sweeps — the core L1 correctness
signal.
"""

import jax.numpy as jnp


def ref_fwd(tokens, emb, w1, b1, w2, b2):
    """Unfused reference: same math as kernels.classifier, via gather."""
    tok = tokens.astype(jnp.int32)
    mask = (tok > 0).astype(jnp.float32)                   # (B, T)
    gathered = emb[tok]                                    # (B, T, D) gather
    gathered = gathered * mask[..., None]
    denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = gathered.sum(axis=1) / denom                  # (B, D)
    h = jnp.maximum(pooled @ w1 + b1, 0.0)
    return h @ w2 + b2

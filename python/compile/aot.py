"""AOT export: lower the L2 model (with its L1 Pallas kernel) to HLO
*text* artifacts the rust runtime loads via PJRT.

HLO **text** — not ``.serialize()`` — is the interchange format: jax
≥ 0.5 emits HloModuleProtos with 64-bit instruction ids, which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import (
    GATHER_VARIANTS,
    VARIANTS,
    example_tokens,
    model_fn,
    model_fn_gather,
)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weight matrices must be fully
    # serialized — the default elides them as `constant({...})`, which
    # the rust-side text parser cannot reconstruct.
    return comp.as_hlo_text(print_large_constants=True)


def export(name: str, out_dir: str) -> str:
    if name in VARIANTS:
        classes, seed = VARIANTS[name]
        fn = model_fn(classes, seed)
    else:
        classes, seed = GATHER_VARIANTS[name]
        fn = model_fn_gather(classes, seed)
    lowered = jax.jit(fn).lower(example_tokens())
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="export a single variant by name"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = [args.only] if args.only else list(VARIANTS) + list(GATHER_VARIANTS)
    for name in names:
        path = export(name, args.out_dir)
        size = os.path.getsize(path)
        print(f"wrote {path} ({size} bytes)")


if __name__ == "__main__":
    main()

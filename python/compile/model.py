"""L2 — the JAX model: deterministic classifier weights + forward pass.

Two model variants are exported:
  * ``classifier`` — 8-class topic classifier (the Ch. 4 `ML` operators
    deciding e.g. "is this tweet about climate change");
  * ``sentiment``  — 2-class sentiment head (the W3 SentimentAnalysis
    stand-in, §2.7.5).

Weights are generated from a fixed seed — the reproduction needs a
*deterministic, realistic* compute graph, not trained accuracy. The
forward pass calls the L1 Pallas kernel so that a single lowering
captures the entire pipeline in one HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels.classifier import (
    BATCH,
    EMBED,
    HIDDEN,
    TOKENS,
    VOCAB,
    classifier_fwd,
)

CLASSES_TOPIC = 8
CLASSES_SENTIMENT = 2


def make_weights(classes: int, seed: int):
    """Deterministic Xavier-ish weights for a model variant."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5 = jax.random.split(k, 5)
    emb = jax.random.normal(k1, (VOCAB, EMBED), jnp.float32) * (EMBED**-0.5)
    w1 = jax.random.normal(k2, (EMBED, HIDDEN), jnp.float32) * (EMBED**-0.5)
    b1 = jax.random.normal(k3, (1, HIDDEN), jnp.float32) * 0.01
    w2 = jax.random.normal(k4, (HIDDEN, classes), jnp.float32) * (HIDDEN**-0.5)
    b2 = jax.random.normal(k5, (1, classes), jnp.float32) * 0.01
    return emb, w1, b1, w2, b2


def model_fn(classes: int, seed: int):
    """Return fn(tokens) -> (logits,) with weights baked in as constants.

    Baking weights keeps the rust side to a single runtime input
    (tokens) and lets XLA constant-fold/pre-layout the weights at AOT
    compile time.
    """
    weights = make_weights(classes, seed)

    def fn(tokens):
        logits = classifier_fwd(tokens, *weights, classes=classes)
        return (logits,)

    return fn


def model_fn_gather(classes: int, seed: int):
    """CPU-tuned forward pass: same weights and math as ``model_fn``,
    but embedding lookup via gather instead of the kernel's one-hot
    matmul. The one-hot form targets the TPU MXU; on the CPU PJRT
    backend a gather avoids the (B·T)×V dense product (§Perf L2
    iteration — ~20× serving speedup with identical outputs)."""
    from .kernels.ref import ref_fwd

    weights = make_weights(classes, seed)

    def fn(tokens):
        return (ref_fwd(tokens, *weights),)

    return fn


#: name → (classes, weight seed); aot.py exports each as <name>.hlo.txt
VARIANTS = {
    "classifier": (CLASSES_TOPIC, 11),
    "sentiment": (CLASSES_SENTIMENT, 23),
}

#: CPU-tuned exports (same weights as their base variant).
GATHER_VARIANTS = {
    "classifier_cpu": (CLASSES_TOPIC, 11),
    "sentiment_cpu": (CLASSES_SENTIMENT, 23),
}


def example_tokens():
    """The example input shape the AOT lowering is specialized to."""
    return jax.ShapeDtypeStruct((BATCH, TOKENS), jnp.int32)

//! Property-based tests over coordinator invariants: routing, batching,
//! region graphs, breakpoint splitting, state migration — driven by the
//! built-in `util::check` mini-harness (seeded generation + shrinking).

use texera_amber::engine::breakpoint::{BpAction, GlobalBreakpoint};
use texera_amber::engine::partitioner::{
    MitigationRoute, PartitionScheme, Partitioner, ShareMode,
};
use texera_amber::maestro::cycles::{feasible_with, is_feasible};
use texera_amber::maestro::enumerate_choices;
use texera_amber::maestro::region_graph::region_graph;
use texera_amber::maestro::regions_of;
use texera_amber::reshape::detector::detect;
use texera_amber::tuple::{Tuple, Value};
use texera_amber::util::check::{check_n, Gen, U64Range, VecGen};
use texera_amber::util::Rng;

/// Fault-injection axis for the chaos fuzzers (`CHAOS_FAULTS=1`, CI
/// matrix): each round seeds a deterministic `FaultPlan` alongside its
/// command stream, so injected failures interleave with
/// pause/checkpoint/scale/migration traffic. The exactness assertions
/// are unchanged — supervised recovery must keep results byte-equal.
fn chaos_faults_enabled() -> bool {
    std::env::var("CHAOS_FAULTS").map(|v| v == "1").unwrap_or(false)
}

/// Out-of-core axis for the chaos fuzzers (`CHAOS_SPILL=1`, CI
/// matrix): each round runs under a seed-derived small memory budget,
/// so the stateful operators (join build, group-by tables, sort runs,
/// live-mat chunks) spill to disk *while* the command stream hits them
/// with pause/checkpoint/scale/migrate traffic. The exactness
/// assertions are unchanged, and every round additionally asserts the
/// execution's spill temp directory is gone after teardown — spill
/// files must be reclaimed on every exit path.
fn chaos_spill_enabled() -> bool {
    std::env::var("CHAOS_SPILL").map(|v| v == "1").unwrap_or(false)
}

/// Apply the spill axis to one round's config: a seed-derived budget
/// between 1 KiB and 16 KiB — far below every fuzzer's resident state,
/// and small enough to drive recursive repartitioning. Returns whether
/// the axis is on so rounds can gate their spill-plane assertions.
fn apply_chaos_spill(cfg: &mut texera_amber::config::Config, seed: u64) -> bool {
    if !chaos_spill_enabled() {
        return false;
    }
    let mut rng = Rng::new(seed ^ 0x5b111);
    cfg.memory_budget_bytes = 1u64 << (10 + rng.below(5));
    true
}

/// Post-teardown leak check shared by the chaos rounds: the per-
/// execution spill directory (if any spill happened) must be removed
/// by the time the `Execution` is dropped — on finish, cancel, abort
/// and panic-recovery paths alike.
fn assert_spill_reclaimed(seed: u64, dir: Option<std::path::PathBuf>) {
    if let Some(dir) = dir {
        assert!(
            !dir.exists(),
            "seed {seed}: leaked spill temp directory {}",
            dir.display()
        );
    }
}

// ---------- routing ----------

/// Any partitioner maps every tuple to a valid destination, and the
/// mapping is stable for hash/range schemes.
#[test]
fn prop_routing_valid_and_stable() {
    struct Case {
        scheme: u8,
        receivers: usize,
        keys: Vec<i64>,
    }
    struct G;
    impl Gen for G {
        type Value = (u8, u64, Vec<u64>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                rng.below(3) as u8,
                1 + rng.below(16),
                (0..rng.below(50) + 1).map(|_| rng.below(10_000)).collect(),
            )
        }
    }
    check_n(11, 128, &G, |(scheme, receivers, keys)| {
        let case = Case {
            scheme: *scheme,
            receivers: *receivers as usize,
            keys: keys.iter().map(|k| *k as i64).collect(),
        };
        let mk = |idx: usize| -> Partitioner {
            let s = match case.scheme {
                0 => PartitionScheme::Hash { key: 0 },
                1 => PartitionScheme::RoundRobin,
                _ => PartitionScheme::Range {
                    key: 0,
                    bounds: (1..case.receivers as i64)
                        .map(|i| Value::Int(i * 1000))
                        .collect(),
                },
            };
            Partitioner::new(s, case.receivers, idx)
        };
        let mut p = mk(0);
        for k in &case.keys {
            let t = Tuple::new(vec![Value::Int(*k)]);
            let d = p.route(&t);
            if d >= case.receivers {
                return false;
            }
            // Hash/range: any sender agrees on the destination.
            if case.scheme != 1 {
                let mut q = mk(3);
                if q.route(&t) != d {
                    return false;
                }
            }
        }
        true
    });
}

/// Mitigation overlays preserve totals: every tuple still goes to
/// exactly one worker, and clearing routes restores base behavior.
#[test]
fn prop_overlay_conservation_and_revert() {
    struct G;
    impl Gen for G {
        type Value = (u64, u64, Vec<u64>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let receivers = 2 + rng.below(8);
            let skewed = rng.below(receivers);
            let keys = (0..100).map(|_| rng.below(5_000)).collect();
            (receivers, skewed, keys)
        }
    }
    check_n(12, 64, &G, |(receivers, skewed, keys)| {
        let n = *receivers as usize;
        let skewed = *skewed as usize;
        let helper = (skewed + 1) % n;
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, n, 0);
        let baseline: Vec<usize> = keys
            .iter()
            .map(|k| p.route(&Tuple::new(vec![Value::Int(*k as i64)])))
            .collect();
        p.set_route(MitigationRoute {
            skewed,
            helper,
            mode: ShareMode::SplitRecords { num: 1, den: 3 },
            epoch: 1,
        });
        for k in keys {
            let d = p.route(&Tuple::new(vec![Value::Int(*k as i64)]));
            if d >= n {
                return false;
            }
        }
        p.clear_route(skewed, helper);
        let after: Vec<usize> = keys
            .iter()
            .map(|k| p.route(&Tuple::new(vec![Value::Int(*k as i64)])))
            .collect();
        baseline == after
    });
}

/// Elastic scaling: under any interleaving of overlay routes, clears
/// and rescale events, every tuple routes to exactly one live receiver
/// (`dest < receivers`), and all senders of an operator compute
/// identical routes for keyed schemes — the determinism invariant the
/// migration protocol depends on (state lands where future tuples go).
#[test]
fn prop_partitioner_scale_events_valid_and_deterministic() {
    use texera_amber::engine::scale::rescale_bounds;

    struct G;
    impl Gen for G {
        type Value = (u8, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            // (scheme kind, initial receivers, event-stream seed)
            (rng.below(3) as u8, 2 + rng.below(7), rng.next_u64())
        }
    }
    check_n(21, 96, &G, |(kind, receivers, stream_seed)| {
        let kind = *kind;
        let mut n = *receivers as usize;
        let bounds: Vec<Value> = (1..n as i64).map(|i| Value::Int(i * 1000)).collect();
        let mk = |idx: usize, n: usize, bounds: &[Value]| -> Partitioner {
            let s = match kind {
                0 => PartitionScheme::Hash { key: 0 },
                1 => PartitionScheme::RoundRobin,
                _ => PartitionScheme::Range { key: 0, bounds: bounds.to_vec() },
            };
            Partitioner::new(s, n, idx)
        };
        // Two senders of the same operator; every control event is
        // applied to both, in the same order.
        let mut pa = mk(0, n, &bounds);
        let mut pb = mk(3, n, &bounds);
        let mut rng = Rng::new(*stream_seed);
        for _ in 0..200 {
            match rng.below(10) {
                // Mostly: route a tuple.
                0..=5 => {
                    let t = Tuple::new(vec![Value::Int(rng.below(8_000) as i64)]);
                    let da = pa.route(&t);
                    if da >= n {
                        return false;
                    }
                    // Keyed schemes: all senders agree.
                    if kind != 1 && pb.route(&t) != da {
                        return false;
                    }
                }
                // Install a random overlay route (indices may be stale
                // after a scale — the partitioner must stay safe).
                6 | 7 => {
                    let skewed = rng.below(10) as usize;
                    let helper = rng.below(10) as usize;
                    let mode = match rng.below(3) {
                        0 => ShareMode::CatchUpAll,
                        1 => ShareMode::SplitRecords {
                            num: 1 + rng.below(9) as u32,
                            den: 10,
                        },
                        _ => ShareMode::SplitKeys(vec![rng.below(8_000)]),
                    };
                    let route = MitigationRoute { skewed, helper, mode, epoch: 1 };
                    pa.set_route(route.clone());
                    pb.set_route(route);
                }
                // Clear a route.
                8 => {
                    let skewed = rng.below(10) as usize;
                    let helper = rng.below(10) as usize;
                    pa.clear_route(skewed, helper);
                    pb.clear_route(skewed, helper);
                }
                // Scale event: new receiver count + recomputed bounds.
                _ => {
                    let new_n = 1 + rng.below(8) as usize;
                    let nb = rescale_bounds(&bounds, new_n);
                    pa.rescale(new_n, Some(nb.clone()));
                    pb.rescale(new_n, Some(nb));
                    n = new_n;
                }
            }
        }
        true
    });
}

/// The vectorized exchange (`route_batch`) is observationally identical
/// to the per-tuple path (`route_with_base`): under any interleaving of
/// mitigation-overlay installs/clears, `set_route` epochs, `rescale`
/// events and batch lengths, the selection vectors reproduce the exact
/// per-tuple destinations AND the per-destination base (natural-share)
/// gauge counts, and every stateful counter (round-robin cursor, SBR
/// windows, catch-up cursor) stays in phase across batches.
#[test]
fn prop_route_batch_matches_per_tuple_under_events() {
    use texera_amber::engine::partitioner::{hash_column, RouteVec};
    use texera_amber::engine::scale::rescale_bounds;
    use texera_amber::tuple::TupleBatch;

    struct G;
    impl Gen for G {
        type Value = (u8, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            // (scheme kind, initial receivers, event-stream seed)
            (rng.below(4) as u8, 2 + rng.below(7), rng.next_u64())
        }
    }
    check_n(22, 96, &G, |(kind, receivers, stream_seed)| {
        let kind = *kind;
        let mut n = *receivers as usize;
        let bounds: Vec<Value> = (1..n as i64).map(|i| Value::Int(i * 1000)).collect();
        let mk = |n: usize, bounds: &[Value]| -> Partitioner {
            let s = match kind {
                0 => PartitionScheme::Hash { key: 0 },
                1 => PartitionScheme::RoundRobin,
                2 => PartitionScheme::OneToOne,
                _ => PartitionScheme::Range { key: 0, bounds: bounds.to_vec() },
            };
            Partitioner::new(s, n, 1)
        };
        // Twin partitioners: `pt` routes per tuple, `pb` per batch.
        // Every control event applies to both, in the same order.
        let mut pt = mk(n, &bounds);
        let mut pb = mk(n, &bounds);
        let mut rng = Rng::new(*stream_seed);
        let mut hashes: Vec<u64> = Vec::new();
        let mut routes = RouteVec::default();
        for _ in 0..60 {
            // 0-4 route, 5-6 install overlay, 7 clear, 8-9 rescale.
            match rng.below(10) {
                // Mostly: route a random batch both ways and compare.
                0..=4 => {
                    let len = 1 + rng.below(40) as usize;
                    let batch: TupleBatch = (0..len)
                        .map(|_| Tuple::new(vec![Value::Int(rng.below(8_000) as i64)]))
                        .collect();
                    let mut dests = Vec::with_capacity(len);
                    let mut bases = vec![0u32; n];
                    for t in batch.iter() {
                        let (b, d) = pt.route_with_base(t);
                        dests.push(d);
                        bases[b] += 1;
                    }
                    hashes.clear();
                    if pb.needs_hashes() {
                        hash_column(&batch, 0, &mut hashes);
                    }
                    pb.route_batch(&batch, &hashes, &mut routes);
                    if routes.broadcast {
                        return false;
                    }
                    if routes.dests(len, n) != dests {
                        return false;
                    }
                    for d in 0..n {
                        if routes.base_counts[d] != bases[d] {
                            return false;
                        }
                    }
                }
                // Install a random overlay route (covering every
                // ShareMode branch; indices may be stale after scale).
                5 | 6 => {
                    let skewed = rng.below(10) as usize;
                    let helper = rng.below(10) as usize;
                    let key = Value::Int(rng.below(8_000) as i64).stable_hash();
                    let mode = match rng.below(5) {
                        0 => ShareMode::CatchUpAll,
                        1 => ShareMode::CatchUpKeys(vec![key]),
                        2 => ShareMode::SplitRecords {
                            num: 1 + rng.below(9) as u32,
                            den: 10,
                        },
                        3 => ShareMode::SplitRecordsKeys {
                            keys: vec![key],
                            num: 1 + rng.below(4) as u32,
                            den: 5,
                        },
                        _ => ShareMode::SplitKeys(vec![key]),
                    };
                    let epoch = rng.below(9);
                    let route = MitigationRoute { skewed, helper, mode, epoch };
                    pt.set_route(route.clone());
                    pb.set_route(route);
                }
                // Clear a route.
                7 => {
                    let skewed = rng.below(10) as usize;
                    let helper = rng.below(10) as usize;
                    pt.clear_route(skewed, helper);
                    pb.clear_route(skewed, helper);
                }
                // Scale event: new receiver count + recomputed bounds.
                _ => {
                    let new_n = 1 + rng.below(8) as usize;
                    let nb = rescale_bounds(&bounds, new_n);
                    pt.rescale(new_n, Some(nb.clone()));
                    pb.rescale(new_n, Some(nb));
                    n = new_n;
                }
            }
        }
        true
    });
}

// ---------- breakpoints ----------

/// COUNT breakpoint protocol: regardless of worker progress order, the
/// breakpoint hits after exactly the target amount in total.
#[test]
fn prop_count_breakpoint_exact() {
    struct G;
    impl Gen for G {
        type Value = (u64, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (2 + rng.below(6), 10 + rng.below(200), rng.next_u64())
        }
    }
    check_n(13, 96, &G, |(workers, total, seed)| {
        let workers = *workers as usize;
        let total = *total;
        let mut bp = GlobalBreakpoint::count(1, total, workers);
        let mut targets = vec![0.0f64; workers];
        for (w, amt) in bp.initial_assignments() {
            targets[w] = amt;
        }
        let mut rng = Rng::new(*seed);
        let mut produced_total = 0.0f64;
        // Simulate until hit; workers make random progress and report.
        for _round in 0..10_000 {
            // Pick the worker that "reaches" first: any with target > 0.
            let candidates: Vec<usize> =
                (0..workers).filter(|&w| targets[w] > 0.0).collect();
            if candidates.is_empty() {
                return false; // no outstanding work but no hit
            }
            let reached = *rng.pick(&candidates);
            produced_total += targets[reached];
            let produced = targets[reached];
            targets[reached] = 0.0;
            match bp.on_target_reached(reached, produced) {
                BpAction::Hit => return (produced_total - total as f64).abs() < 1e-9,
                BpAction::StartTimer => {
                    // Timer fires; inquiries report random partial
                    // progress.
                    if let BpAction::Inquire(missing) = bp.on_timeout() {
                        let mut last = BpAction::None;
                        for w in missing {
                            let partial =
                                (targets[w] * rng.f64()).floor().clamp(0.0, targets[w]);
                            produced_total += partial;
                            targets[w] = 0.0;
                            last = bp.on_inquiry_report(w, partial);
                        }
                        match last {
                            BpAction::Hit => {
                                return (produced_total - total as f64).abs() < 1e-9
                            }
                            BpAction::Assign(assignments) => {
                                for (w, amt) in assignments {
                                    targets[w] = amt;
                                }
                            }
                            _ => {}
                        }
                    }
                }
                BpAction::Assign(assignments) => {
                    for (w, amt) in assignments {
                        targets[w] = amt;
                    }
                }
                _ => {}
            }
        }
        false
    });
}

// ---------- reshape detector ----------

/// Detector invariants: pairs are disjoint, skewed worker satisfies
/// both inequalities vs each helper, helpers not in `excluded`.
#[test]
fn prop_detector_invariants() {
    let gen = VecGen { inner: U64Range(0, 2_000), max_len: 24 };
    check_n(14, 128, &gen, |loads_u| {
        if loads_u.len() < 2 {
            return true;
        }
        let loads: Vec<f64> = loads_u.iter().map(|x| *x as f64).collect();
        let r = detect(&loads, &[], 100.0, 100.0, 2);
        let mut used = std::collections::HashSet::new();
        for (s, helpers) in &r.pairs {
            if !used.insert(*s) {
                return false;
            }
            for h in helpers {
                if !used.insert(*h) {
                    return false;
                }
                if !(loads[*s] >= 100.0 && loads[*s] - loads[*h] >= 100.0) {
                    return false;
                }
            }
        }
        true
    });
}

// ---------- maestro ----------

/// Random layered DAGs: regions partition the operators; every
/// enumerated choice is feasible; feasible workflows need no choice.
#[test]
fn prop_region_partition_and_choices() {
    use texera_amber::engine::{OpSpec, Workflow};
    use texera_amber::operators::basic::Filter;
    use texera_amber::operators::basic::Cmp;
    use texera_amber::workloads::VecSource;

    struct G;
    impl Gen for G {
        type Value = u64;
        fn generate(&self, rng: &mut Rng) -> u64 {
            rng.next_u64()
        }
    }
    check_n(15, 48, &G, |seed| {
        let mut rng = Rng::new(*seed);
        // Random workflow: 1-2 sources, 2-5 unary ops (some blocking),
        // 0-2 joins wired to random upstream ops.
        let mut w = Workflow::new();
        let mut pool: Vec<usize> = Vec::new();
        for i in 0..1 + rng.below(2) {
            let s = w.add(OpSpec::source(&format!("src{i}"), 1, |_, _| {
                Box::new(VecSource::new(Vec::new()))
            }));
            pool.push(s);
        }
        for i in 0..2 + rng.below(4) {
            let blocking = rng.chance(0.3);
            let mut spec = OpSpec::unary(
                &format!("u{i}"),
                1,
                PartitionScheme::RoundRobin,
                |_, _| Box::new(Filter::new(0, Cmp::Ge, Value::Int(0))),
            );
            if blocking {
                spec = spec.with_blocking(vec![0]);
            }
            let op = w.add(spec);
            let from = *rng.pick(&pool);
            w.connect(from, op, 0);
            pool.push(op);
        }
        for i in 0..rng.below(3) {
            let j = w.add(OpSpec::binary(
                &format!("j{i}"),
                1,
                [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
                vec![0],
                |_, _| {
                    Box::new(texera_amber::operators::HashJoin::new(0, 0))
                },
            ));
            let a = *rng.pick(&pool);
            let b = *rng.pick(&pool);
            w.connect(a, j, 0);
            w.connect(b, j, 1);
            pool.push(j);
        }
        // Invariant 1: regions partition ops.
        let regions = regions_of(&w);
        let mut seen = vec![false; w.ops.len()];
        for r in &regions {
            for &op in &r.ops {
                if seen[op] {
                    return false;
                }
                seen[op] = true;
            }
        }
        if !seen.iter().all(|&b| b) {
            return false;
        }
        // Invariant 2: dep endpoints valid.
        let g = region_graph(&w);
        for (u, v, _) in &g.deps {
            if *u >= regions.len() || *v >= regions.len() {
                return false;
            }
        }
        // Invariant 3: enumerate → all feasible; feasible → empty set.
        let choices = enumerate_choices(&w, 2);
        if is_feasible(&w) {
            if choices != vec![Vec::new()] {
                return false;
            }
        } else {
            for c in &choices {
                if !feasible_with(&w, c) {
                    return false;
                }
            }
        }
        true
    });
}

// ---------- columnar ≡ row data plane ----------

/// The struct-of-arrays data plane is observationally identical to the
/// row-major one: the same workflow (columnar filter, hash-hash join
/// with shipped hash columns, hash-partitioned typed count sink) run
/// with `Config::columnar` on vs off yields byte-identical sink
/// multisets and per-key counter gauges at batch 32 / 256 / 1024.
#[test]
fn prop_columnar_plane_matches_row_plane() {
    for batch_size in [32usize, 256, 1024] {
        let row = columnar_equiv_run(batch_size, false);
        let col = columnar_equiv_run(batch_size, true);
        assert_eq!(row.0, col.0, "batch {batch_size}: sink multiset differs");
        assert_eq!(row.1, col.1, "batch {batch_size}: per-key counts differ");
    }
}

/// One run; returns (canonical collect-sink multiset, per-key counts).
fn columnar_equiv_run(batch_size: usize, columnar: bool) -> (Vec<String>, Vec<u64>) {
    use texera_amber::config::Config;
    use texera_amber::engine::{Execution, OpSpec, Workflow};
    use texera_amber::operators::basic::{Cmp, Filter};
    use texera_amber::operators::{CollectSink, CountByKeySink, HashJoin, SinkHandle};
    use texera_amber::workloads::VecSource;

    const ROWS: usize = 50_000;
    const KEYS: i64 = 29;

    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                Tuple::new(vec![Value::Int(i as i64 % KEYS), Value::Int(i as i64 % 11)])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(Filter::new(1, Cmp::Ne, Value::Int(5))),
    ));
    let dim = w.add(OpSpec::source("dim", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..KEYS)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(3 * k)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    // Hash on both ports: the exchange ships its hash columns, and the
    // join's build/probe reuse them verbatim.
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0)),
    ));
    let collect_h = SinkHandle::new(0);
    let ch = collect_h.clone();
    let collect = w.add(OpSpec::unary(
        "collect",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(ch.clone())),
    ));
    // Hash-partitioned typed count sink: field 2 of the build⋈probe
    // concat is the probe key (Int column → vectorized count path).
    let count_h = SinkHandle::new(KEYS as usize);
    let kh = count_h.clone();
    let count = w.add(OpSpec::unary(
        "count",
        2,
        PartitionScheme::Hash { key: 2 },
        move |_, _| Box::new(CountByKeySink::new(kh.clone(), 2)),
    ));
    w.connect(scan, filter, 0);
    w.connect(dim, join, 0);
    w.connect(filter, join, 1);
    w.connect(join, collect, 0);
    w.connect(join, count, 0);
    let cfg = Config {
        batch_size,
        ctrl_check_interval: batch_size,
        columnar,
        ..Config::default()
    };
    Execution::start(w, cfg).join();
    let mut rows: Vec<String> = collect_h.tuples().iter().map(|t| format!("{t:?}")).collect();
    rows.sort_unstable();
    let counts: Vec<u64> = (0..KEYS as usize).map(|k| count_h.count_of(k)).collect();
    (rows, counts)
}

// ---------- chaos: control-plane interleavings ----------

/// Seeded command-fuzzer over one workflow: random interleavings of
/// pause/resume, checkpoint, Reshape-style mitigation routes, and
/// elastic scale commands must preserve the exact sink result. Three
/// rounds per run, each at a different batch size (32 / 256 / 1024) so
/// the vectorized exchange is fuzzed across buffering regimes; the
/// batch-32 round runs with the columnar plane disabled so the
/// row-major fallback is fuzzed too. `CHAOS_SEED` (CI matrix) shifts
/// the whole command/timing stream.
#[test]
fn prop_chaos_control_interleavings_preserve_results() {
    let base: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    for (round, batch_size, columnar) in [(0u64, 256usize, true), (1, 1024, true), (2, 32, false)]
    {
        chaos_round(base.wrapping_mul(1000).wrapping_add(round), batch_size, columnar);
    }
}

fn chaos_round(seed: u64, batch_size: usize, columnar: bool) {
    use std::time::Duration;
    use texera_amber::config::Config;
    use texera_amber::engine::{ControlMessage, Execution, OpSpec, WorkerId, Workflow};
    use texera_amber::operators::basic::{Cmp, Filter};
    use texera_amber::operators::group_by::{AggKind, GroupByFinal, GroupByPartial};
    use texera_amber::operators::{CollectSink, SinkHandle};
    use texera_amber::workloads::VecSource;

    const ROWS: usize = 200_000;
    const KEYS: i64 = 53;

    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                Tuple::new(vec![Value::Int(i as i64 % KEYS), Value::Int(i as i64 % 7)])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| {
            let mut f = Filter::new(1, Cmp::Ne, Value::Int(3));
            f.cost_ns = 400;
            Box::new(f)
        },
    ));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(0, 1, AggKind::Sum)),
    ));
    let fin = w.add(
        OpSpec::unary(
            "gb_final",
            2,
            PartitionScheme::Hash { key: 0 },
            |_, _| Box::new(GroupByFinal::new(AggKind::Sum)),
        )
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, filter, 0);
    w.connect(filter, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);

    let mut cfg = Config { batch_size, columnar, ..Config::default() };
    if chaos_faults_enabled() {
        // Panic + stall faults at seed-derived replay positions on the
        // high-volume operators; the supervisor must detect each,
        // recover (checkpoint restore or scratch re-run + control
        // replay), and still land the exact sink result below.
        use texera_amber::engine::{Fault, FaultPlan, WorkerId as Wid};
        let mut frng = Rng::new(seed ^ 0xfa);
        let victims = [scan, filter, partial];
        let mut plan = FaultPlan::default();
        plan.push(Fault::panic_at(
            Wid::new(victims[frng.below(3) as usize], frng.below(2) as usize),
            64 + frng.below(50_000),
        ));
        plan.push(Fault::stall_at(
            Wid::new(victims[frng.below(3) as usize], frng.below(2) as usize),
            64 + frng.below(50_000),
            350,
        ));
        cfg = Config {
            ft_log: true,
            heartbeat_timeout_ms: 200,
            checkpoint_interval_ms: 25,
            recovery_backoff_ms: 5,
            fault_plan: plan,
            ..cfg
        };
    }
    apply_chaos_spill(&mut cfg, seed);
    let exec = Execution::start(w, cfg);
    let mut rng = Rng::new(seed);
    let mut paused = false;
    // Worker counts as far as the driver knows (a refused scale —
    // fence duration zero — leaves them unchanged). The scan is
    // scalable too (universal elasticity: splittable scan ranges).
    let mut counts = [2usize, 2, 2, 2]; // scan, filter, partial, fin
    let scalable = [scan, filter, partial, fin];
    let mut epoch = 1u64;
    for _ in 0..14 {
        std::thread::sleep(Duration::from_millis(1 + rng.below(8)));
        match rng.below(8) {
            0 => {
                if !paused {
                    exec.pause();
                    paused = true;
                }
            }
            1 => {
                if paused {
                    exec.resume();
                    paused = false;
                }
            }
            2 => {
                // Quiesced checkpoint (internally pauses + resumes).
                if !paused {
                    let _ = exec.checkpoint();
                }
            }
            3..=5 => {
                let which = rng.below(4) as usize;
                let target = 1 + rng.below(4) as usize;
                if exec.scale_operator(scalable[which], target) > Duration::ZERO {
                    counts[which] = target;
                }
            }
            _ => {
                // Reshape-style SBR mitigation on the scan→filter edge
                // (stateless target: exact under any record split).
                if counts[1] >= 2 {
                    epoch += 1;
                    let skewed = rng.below(counts[1] as u64) as usize;
                    let helper = (skewed + 1) % counts[1];
                    for sw in 0..counts[0] {
                        exec.send_control(
                            WorkerId::new(scan, sw),
                            ControlMessage::UpdateRoute {
                                target_op: filter,
                                route: MitigationRoute {
                                    skewed,
                                    helper,
                                    mode: ShareMode::SplitRecords {
                                        num: 1 + rng.below(500) as u32,
                                        den: 1000,
                                    },
                                    epoch,
                                },
                            },
                        );
                    }
                }
            }
        }
    }
    if paused {
        exec.resume();
    }
    exec.join();

    // Ground truth, computed directly.
    let mut expect: std::collections::HashMap<i64, f64> = std::collections::HashMap::new();
    for i in 0..ROWS {
        let (k, v) = (i as i64 % KEYS, i as i64 % 7);
        if v != 3 {
            *expect.entry(k).or_insert(0.0) += v as f64;
        }
    }
    let mut got: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    got.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(got.len(), expect.len(), "seed {seed}: wrong group count");
    for (k, s) in &got {
        assert_eq!(expect[k], *s, "seed {seed}: wrong sum for key {k}");
    }

    let spill_dir = exec.spill_dir();
    drop(exec);
    assert_spill_reclaimed(seed, spill_dir);
}

// ---------- chaos: universal elasticity ----------

/// Seeded command-fuzzer over the formerly refusal-only operator
/// classes: a *source* scan, a *broadcast-input* hash join, a
/// *scatter-merge* range sort, and a *mixed-port* enrich (broadcast
/// dict + hash-partitioned counts in one operator) are all scaled
/// up/down at random points, interleaved with pause/resume, quiesced
/// checkpoints and Reshape-style mitigation routes. Both sink
/// multisets must be byte-identical to a direct computation at batch
/// 32 / 256 / 1024;
/// the batch-32 round runs with the columnar plane disabled so the
/// row-major fallback is fuzzed too. `CHAOS_SEED` (CI matrix) shifts
/// the whole command/timing stream.
#[test]
fn prop_chaos_universal_elasticity_preserves_results() {
    let base: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    for (round, batch_size, columnar) in [(0u64, 256usize, true), (1, 1024, true), (2, 32, false)]
    {
        universal_chaos_round(
            base.wrapping_mul(7000).wrapping_add(round),
            batch_size,
            columnar,
        );
    }
}

fn universal_chaos_round(seed: u64, batch_size: usize, columnar: bool) {
    use std::time::Duration;
    use texera_amber::config::Config;
    use texera_amber::engine::{ControlMessage, Execution, OpSpec, WorkerId, Workflow};
    use texera_amber::operators::basic::MapUdf;
    use texera_amber::operators::enrich::{DICT, EVENT};
    use texera_amber::operators::sort::SortWorker;
    use texera_amber::operators::{CollectSink, Enrich, HashJoin, SinkHandle};
    use texera_amber::workloads::VecSource;

    const ROWS: usize = 120_000;
    const KEYS: i64 = 41;

    let mut w = Workflow::new();
    // Probe stream: (key, val) rows, round-robin-partitioned scan. A
    // small per-tuple parse cost keeps the scan alive long enough that
    // source-scale commands land mid-read at every batch size.
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..ROWS)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    Tuple::new(vec![
                        Value::Int(i as i64 % KEYS),
                        Value::Int(i as i64 % 9),
                    ])
                })
                .collect();
            Box::new(VecSource::new(rows))
        },
        |_, _| Box::new(MapUdf::identity(2000)),
    ));
    // Build side: one row per key, broadcast to every join worker.
    let dim = w.add(OpSpec::source("dim", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..KEYS)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(2 * k)]))
            .collect::<Vec<_>>()
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t)
            .collect();
        Box::new(VecSource::new(rows))
    }));
    // Second build side for the mixed-port enrich branch: one
    // (key, bonus) row per key, broadcast on the dict port.
    let dim2 = w.add(OpSpec::source("dim2", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..KEYS)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(2 * k + 1)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    // Broadcast-input class: build port 0 broadcast, probe port 1 RR.
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Broadcast, PartitionScheme::RoundRobin],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0)),
    ));
    // Scatter-merge class: range sort on the probe value (field 3 of
    // the build⋈probe concat), with the EOF peer barrier armed.
    let sort_bounds = vec![Value::Int(4)];
    let sb = sort_bounds.clone();
    let sortw = w.add(
        OpSpec::unary(
            "sort",
            2,
            PartitionScheme::Range { key: 3, bounds: sort_bounds },
            move |idx, _| Box::new(SortWorker::new(3, idx as u64, sb.clone())),
        )
        .with_blocking(vec![0])
        .with_scatter_merge(),
    );
    // Mixed-port state class: broadcast dict on one port, keyed
    // per-key counts on the other. Scaling it must replicate the dict
    // while re-sharding (not replicating) the partitioned counts.
    let enrich = w.add(OpSpec::binary(
        "enrich",
        2,
        [PartitionScheme::Broadcast, PartitionScheme::Hash { key: 0 }],
        vec![DICT],
        |_, _| Box::new(Enrich::new()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    let handle2 = SinkHandle::new(0);
    let h2 = handle2.clone();
    let sink2 = w.add(OpSpec::unary(
        "sink2",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h2.clone())),
    ));
    w.connect(dim, join, 0);
    w.connect(scan, join, 1);
    w.connect(join, sortw, 0);
    w.connect(sortw, sink, 0);
    w.connect(dim2, enrich, DICT);
    w.connect(scan, enrich, EVENT);
    w.connect(enrich, sink2, 0);

    let mut cfg = Config { batch_size, columnar, ..Config::default() };
    if chaos_faults_enabled() {
        // Timing-only faults on this fuzzer: delayed batches perturb
        // exchange interleaving under scale fences without triggering
        // recovery (recovery composing with live-mat/scale epochs is
        // exercised by the control-interleaving fuzzer).
        use texera_amber::engine::{Fault, FaultPlan, WorkerId as Wid};
        let mut frng = Rng::new(seed ^ 0xfa);
        let mut plan = FaultPlan::default();
        plan.push(Fault::delay_nth(Wid::new(scan, 0), join, 1 + frng.below(40), 30));
        plan.push(Fault::delay_nth(Wid::new(scan, 1), enrich, 1 + frng.below(40), 30));
        cfg = Config { fault_plan: plan, ..cfg };
    }
    let spill_on = apply_chaos_spill(&mut cfg, seed);
    let exec = Execution::start(w, cfg);
    let mut rng = Rng::new(seed);
    let mut paused = false;
    // Tracked worker counts (a refused scale leaves them unchanged).
    let mut counts = [2usize, 2, 2, 2]; // scan, join, sortw, enrich
    let scalable = [scan, join, sortw, enrich];
    let mut epoch = 1u64;
    for _ in 0..14 {
        std::thread::sleep(Duration::from_millis(1 + rng.below(8)));
        match rng.below(8) {
            0 => {
                if !paused {
                    exec.pause();
                    paused = true;
                }
            }
            1 => {
                if paused {
                    exec.resume();
                    paused = false;
                }
            }
            2 => {
                if !paused {
                    let _ = exec.checkpoint();
                }
            }
            3..=6 => {
                // The heart of the fuzz: scale a source, a
                // broadcast-input join, a scatter-merge sort, or a
                // mixed-state enrich.
                let which = rng.below(4) as usize;
                let target = 1 + rng.below(4) as usize;
                if exec.scale_operator(scalable[which], target) > Duration::ZERO {
                    counts[which] = target;
                }
            }
            _ => {
                // Mitigation on the join→sort range edge: SBR record
                // splits create foreign runs, exercising the
                // scattered-state barrier under scaling.
                if counts[2] >= 2 {
                    epoch += 1;
                    let skewed = rng.below(counts[2] as u64) as usize;
                    let helper = (skewed + 1) % counts[2];
                    for jw in 0..counts[1] {
                        exec.send_control(
                            WorkerId::new(join, jw),
                            ControlMessage::UpdateRoute {
                                target_op: sortw,
                                route: MitigationRoute {
                                    skewed,
                                    helper,
                                    mode: ShareMode::SplitRecords {
                                        num: 1 + rng.below(400) as u32,
                                        den: 1000,
                                    },
                                    epoch,
                                },
                            },
                        );
                    }
                }
            }
        }
    }
    if paused {
        exec.resume();
    }
    let summary = exec.join();

    // Ground truth, computed directly: every scan row joins exactly
    // its key's dim row → (k, 2k, k, v).
    let mut expect: Vec<(i64, i64, i64, i64)> = (0..ROWS)
        .map(|i| {
            let (k, v) = (i as i64 % KEYS, i as i64 % 9);
            (k, 2 * k, k, v)
        })
        .collect();
    expect.sort_unstable();
    let mut got: Vec<(i64, i64, i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| {
            (
                t.get(0).as_int().unwrap(),
                t.get(1).as_int().unwrap(),
                t.get(2).as_int().unwrap(),
                t.get(3).as_int().unwrap(),
            )
        })
        .collect();
    got.sort_unstable();
    assert_eq!(
        got.len(),
        expect.len(),
        "seed {seed} batch {batch_size}: wrong row count"
    );
    assert_eq!(got, expect, "seed {seed} batch {batch_size}: multiset differs");

    // Enrich branch: every scan row becomes (k, v + bonus_k, 1); at
    // EOF each worker flushes its count shards as (k, count_k, -1).
    let mut expect2: Vec<(i64, i64, i64)> = (0..ROWS)
        .map(|i| {
            let (k, v) = (i as i64 % KEYS, i as i64 % 9);
            (k, v + 2 * k + 1, 1)
        })
        .collect();
    for k in 0..KEYS {
        let cnt = (ROWS as i64 - 1 - k) / KEYS + 1; // |{i < ROWS : i ≡ k}|
        expect2.push((k, cnt, -1));
    }
    expect2.sort_unstable();
    let mut got2: Vec<(i64, i64, i64)> = handle2
        .tuples()
        .iter()
        .map(|t| {
            (
                t.get(0).as_int().unwrap(),
                t.get(1).as_int().unwrap(),
                t.get(2).as_int().unwrap(),
            )
        })
        .collect();
    got2.sort_unstable();
    assert_eq!(
        got2, expect2,
        "seed {seed} batch {batch_size}: enrich multiset differs"
    );

    if spill_on {
        // The sort's blocking state alone is megabytes against a
        // ≤ 16 KiB budget: this round must actually have gone to disk
        // (the exactness checks above then pin spilled ≡ resident).
        assert!(
            summary.spill.bytes_spilled > 0,
            "seed {seed}: spill axis on but nothing spilled: {:?}",
            summary.spill
        );
    }
    let spill_dir = exec.spill_dir();
    drop(exec);
    assert_spill_reclaimed(seed, spill_dir);
}

// ---------- chaos: live plan migration ----------

/// Seeded command-fuzzer over whole-plan migrations: repartition-scheme
/// swaps on a live edge (Round-Robin / Hash / Range with bounds derived
/// from the fence's parked sample), live materialization insertion and
/// removal, and multi-step worker re-plans, interleaved with
/// pause/resume, quiesced checkpoints and elastic scale commands — at
/// batch 32 / 256 / 1024, with the batch-32 round on the row-major
/// plane. The pipeline carries a mixed-port broadcast operator
/// ([`Enrich`]: broadcast dict + partitioned counts), so every fence
/// crosses both state classes. The sink multiset must be byte-identical
/// to a direct computation. `CHAOS_SEED` (CI matrix) shifts the whole
/// command/timing stream.
///
/// [`Enrich`]: texera_amber::operators::Enrich
#[test]
fn prop_chaos_migration_preserves_results() {
    let base: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    for (round, batch_size, columnar) in [(0u64, 256usize, true), (1, 1024, true), (2, 32, false)]
    {
        migration_chaos_round(
            base.wrapping_mul(13000).wrapping_add(round),
            batch_size,
            columnar,
        );
    }
}

fn migration_chaos_round(seed: u64, batch_size: usize, columnar: bool) {
    use std::time::Duration;
    use texera_amber::config::Config;
    use texera_amber::engine::{Execution, OpSpec, PlanDelta, Workflow};
    use texera_amber::operators::basic::{Cmp, Filter, MapUdf};
    use texera_amber::operators::enrich::{DICT, EVENT};
    use texera_amber::operators::{CollectSink, Enrich, SinkHandle};
    use texera_amber::workloads::VecSource;

    const ROWS: usize = 120_000;
    const KEYS: i64 = 37;

    let mut w = Workflow::new();
    let dict = w.add(OpSpec::source("dict", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..KEYS)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(100 + k)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    // A per-tuple parse cost keeps the scan alive long enough that
    // migrations land mid-stream at every batch size.
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..ROWS)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    Tuple::new(vec![
                        Value::Int(i as i64 % KEYS),
                        Value::Int(i as i64 % 13),
                    ])
                })
                .collect();
            Box::new(VecSource::new(rows))
        },
        |_, _| Box::new(MapUdf::identity(2000)),
    ));
    let enrich = w.add(OpSpec::binary(
        "enrich",
        2,
        [PartitionScheme::Broadcast, PartitionScheme::Hash { key: 0 }],
        vec![DICT],
        |_, _| Box::new(Enrich::new()),
    ));
    // Stateless pass-through (field 1 ≥ 0 for every event and summary
    // row): the migrated edge is enrich → filter.
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(Filter::new(1, Cmp::Ge, Value::Int(0))),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(dict, enrich, DICT);
    w.connect(scan, enrich, EVENT);
    w.connect(enrich, filter, 0);
    w.connect(filter, sink, 0);

    let mut cfg = Config { batch_size, columnar, ..Config::default() };
    if chaos_faults_enabled() {
        // Timing-only faults on the migrated pipeline: delays land
        // around repartition/materialization fences; per-edge FIFO
        // holds, so the multiset stays byte-identical.
        use texera_amber::engine::{Fault, FaultPlan, WorkerId as Wid};
        let mut frng = Rng::new(seed ^ 0xfa);
        let mut plan = FaultPlan::default();
        plan.push(Fault::delay_nth(Wid::new(scan, 0), enrich, 1 + frng.below(40), 30));
        plan.push(Fault::delay_nth(Wid::new(enrich, 0), filter, 1 + frng.below(40), 30));
        cfg = Config { fault_plan: plan, ..cfg };
    }
    // Under the spill axis the InsertMat/RemoveMat arms below run the
    // live materialization store disk-backed (chunked past the budget).
    apply_chaos_spill(&mut cfg, seed);
    let exec = Execution::start(w, cfg);
    let mut rng = Rng::new(seed);
    let mut paused = false;
    // Driver's view of whether the enrich→filter edge is currently
    // materialized (a refused migration leaves it unchanged).
    let mut mat_on = false;
    for _ in 0..14 {
        std::thread::sleep(Duration::from_millis(1 + rng.below(8)));
        match rng.below(8) {
            0 => {
                if !paused {
                    exec.pause();
                    paused = true;
                }
            }
            1 => {
                if paused {
                    exec.resume();
                    paused = false;
                }
            }
            2 => {
                // Quiesced checkpoint (internally pauses + resumes).
                if !paused {
                    let _ = exec.checkpoint();
                }
            }
            3 => {
                // Elastic scale interleaved with migrations; scaling
                // enrich crosses the mixed broadcast/partitioned state.
                let target = 1 + rng.below(4) as usize;
                let which = if rng.below(2) == 0 { scan } else { enrich };
                let _ = exec.scale_operator(which, target);
            }
            4 => {
                // Repartition the live edge into the filter; the Range
                // arm derives bounds from the fence's parked sample.
                let scheme = match rng.below(3) {
                    0 => PartitionScheme::RoundRobin,
                    1 => PartitionScheme::Hash { key: 0 },
                    _ => PartitionScheme::Range { key: 0, bounds: Vec::new() },
                };
                let _ = exec.migrate(PlanDelta::Repartition { op: filter, port: 0, scheme });
            }
            5 => {
                if !mat_on {
                    mat_on = exec
                        .migrate(PlanDelta::InsertMat { from: enrich, to: filter, to_port: 0 })
                        .applied;
                }
            }
            6 => {
                if mat_on
                    && exec
                        .migrate(PlanDelta::RemoveMat { from: enrich, to: filter, to_port: 0 })
                        .applied
                {
                    mat_on = false;
                }
            }
            _ => {
                // Multi-step re-plan: two fenced scale steps under one
                // migration (abort-and-restore on any refusal).
                let _ = exec.migrate(PlanDelta::Replan {
                    workers: vec![
                        (scan, 1 + rng.below(3) as usize),
                        (filter, 1 + rng.below(3) as usize),
                    ],
                });
            }
        }
    }
    if paused {
        exec.resume();
    }
    exec.join();

    // Ground truth, computed directly: every scan row becomes
    // (k, v + 100 + k, 1); at EOF each enrich worker flushes its count
    // shards as (k, count_k, -1). The filter passes everything.
    let mut expect: Vec<(i64, i64, i64)> = (0..ROWS)
        .map(|i| {
            let (k, v) = (i as i64 % KEYS, i as i64 % 13);
            (k, v + 100 + k, 1)
        })
        .collect();
    for k in 0..KEYS {
        let cnt = (ROWS as i64 - 1 - k) / KEYS + 1; // |{i < ROWS : i ≡ k}|
        expect.push((k, cnt, -1));
    }
    expect.sort_unstable();
    let mut got: Vec<(i64, i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| {
            (
                t.get(0).as_int().unwrap(),
                t.get(1).as_int().unwrap(),
                t.get(2).as_int().unwrap(),
            )
        })
        .collect();
    got.sort_unstable();
    assert_eq!(
        got.len(),
        expect.len(),
        "seed {seed} batch {batch_size}: wrong row count"
    );
    assert_eq!(got, expect, "seed {seed} batch {batch_size}: multiset differs");

    let spill_dir = exec.spill_dir();
    drop(exec);
    assert_spill_reclaimed(seed, spill_dir);
}

// ---------- splittable scan ranges ----------

/// Source split/replay contract (universal elasticity): for any
/// built-in `TupleSource`, any consumed prefix and any split arity `n`,
/// the multiset union of the `n` sub-ranges equals the unsplit
/// remainder, and replay from any recorded position of a sub-range is
/// byte-identical to its first reading.
#[test]
fn prop_source_split_union_and_replay() {
    use texera_amber::workloads::dsb::{SkewProfile, WebSalesSource};
    use texera_amber::workloads::synthetic::ShiftingSource;
    use texera_amber::workloads::tpch::LineitemSource;
    use texera_amber::workloads::tweets::TweetSource;
    use texera_amber::workloads::{TupleSource, VecSource};

    fn drain(s: &mut dyn TupleSource) -> Vec<Tuple> {
        std::iter::from_fn(|| s.next_tuple()).collect()
    }
    /// Canonical multiset key (tuples have no Ord).
    fn canon(mut v: Vec<Tuple>) -> Vec<String> {
        let mut keys: Vec<String> = v.drain(..).map(|t| format!("{t:?}")).collect();
        keys.sort_unstable();
        keys
    }

    struct G;
    impl Gen for G {
        type Value = (u8, u64, u64, u64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            // (source kind, total rows, consumed prefix %, arity, seed)
            (
                rng.below(5) as u8,
                50 + rng.below(400),
                rng.below(100),
                1 + rng.below(6),
                rng.next_u64(),
            )
        }
    }
    check_n(23, 48, &G, |(kind, total, pre_pct, arity, seed)| {
        let total = *total as usize;
        let n = *arity as usize;
        let mk = |parts: usize, idx: usize| -> Box<dyn TupleSource> {
            match kind {
                0 => Box::new(VecSource::strided(
                    std::sync::Arc::new(
                        (0..total as i64)
                            .map(|i| Tuple::new(vec![Value::Int(i)]))
                            .collect(),
                    ),
                    idx,
                    parts,
                )),
                1 => Box::new(TweetSource::new(total, parts, idx, *seed | 1)),
                2 => Box::new(ShiftingSource::new(total, parts, idx, *seed | 1)),
                3 => Box::new(LineitemSource::with_rows(total, parts, idx, *seed | 1)),
                _ => Box::new(WebSalesSource::new(
                    total,
                    parts,
                    idx,
                    *seed | 1,
                    SkewProfile::default(),
                )),
            }
        };
        // A 2-way partition like a deployed scan worker would hold.
        let mut src = mk(2, 1);
        let part_len = src.len_hint().unwrap();
        let pre = (part_len * *pre_pct as usize) / 100;
        for _ in 0..pre {
            if src.next_tuple().is_none() {
                return false;
            }
        }
        // Reference remainder via fork (also checks fork ≡ original).
        let mut fork = match src.fork() {
            Some(f) => f,
            None => return false,
        };
        let remainder = canon(drain(fork.as_mut()));
        // Split and union the sub-ranges.
        let subs = match src.split(n) {
            Some(s) => s,
            None => return false,
        };
        if subs.len() != n {
            return false;
        }
        let mut union: Vec<Tuple> = Vec::new();
        let mut rng = Rng::new(seed.wrapping_add(17));
        for mut sub in subs {
            let out = drain(sub.as_mut());
            // Replay from a random recorded position is identical.
            let p = (rng.below(out.len() as u64 + 1)) as usize;
            sub.seek(p);
            let tail = drain(sub.as_mut());
            if tail != out[p..] {
                return false;
            }
            // Full reset replays the whole sub-range.
            sub.reset();
            if drain(sub.as_mut()) != out {
                return false;
            }
            union.extend(out);
        }
        canon(union) == remainder
    });
}

// ---------- estimator ----------

/// Mean estimator: prediction within [min, max] of sample; ε shrinks
/// monotonically in n for constant-variance inputs.
#[test]
fn prop_estimator_bounds() {
    let gen = VecGen { inner: U64Range(0, 10_000), max_len: 64 };
    check_n(16, 128, &gen, |xs| {
        if xs.len() < 2 {
            return true;
        }
        let mut e = texera_amber::reshape::MeanEstimator::new(128);
        for x in xs {
            e.observe(*x as f64);
        }
        let p = e.predict();
        let lo = *xs.iter().min().unwrap() as f64;
        let hi = *xs.iter().max().unwrap() as f64;
        p >= lo - 1e-9 && p <= hi + 1e-9 && e.standard_error() >= 0.0
    });
}

// ---------- serving layer ----------

/// Service-fuzzer axis (`CHAOS_SERVICE=1`, CI matrix): more trials of
/// the multi-tenant action fuzzer below.
fn chaos_service_enabled() -> bool {
    std::env::var("CHAOS_SERVICE").map(|v| v == "1").unwrap_or(false)
}

/// With a single tenant active, the cross-workflow arbiter is exactly
/// Maestro's per-region `assign_workers` on single-region workflows:
/// same groups, same marginal gains, same strict-`>` tie-breaking.
#[test]
fn prop_arbiter_matches_assign_workers_single_tenant() {
    use std::collections::HashMap;
    use texera_amber::engine::{Emitter, OpSpec, Operator, Workflow};
    use texera_amber::maestro::cost::{assign_workers, cardinalities, CostParams};
    use texera_amber::maestro::regions_of;
    use texera_amber::service::{arbitrate, ArbiterJob};
    use texera_amber::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    struct G;
    impl Gen for G {
        // (source rows, spare budget, per-op shape codes)
        type Value = (u64, u64, Vec<u64>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                100 + rng.below(100_000),
                rng.below(24),
                (0..2 + rng.below(5)).map(|_| rng.below(1000)).collect(),
            )
        }
    }
    check_n(23, 96, &G, |(rows, spare, codes)| {
        // Random chain: authored counts 1–3, schemes cycling through
        // one-to-one (group merging!), round-robin and hash.
        let mut w = Workflow::new();
        let n_rows = *rows as usize;
        let mut prev = w.add(OpSpec::source(
            "scan",
            1 + (codes[0] % 3) as usize,
            move |idx, parts| {
                let rows: Vec<Tuple> = (0..n_rows)
                    .skip(idx)
                    .step_by(parts)
                    .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
                    .collect();
                Box::new(VecSource::new(rows))
            },
        ));
        for (i, code) in codes.iter().enumerate().skip(1) {
            let scheme = match code % 3 {
                0 => PartitionScheme::OneToOne,
                1 => PartitionScheme::RoundRobin,
                _ => PartitionScheme::Hash { key: 0 },
            };
            let op = w.add(OpSpec::unary(
                &format!("op{i}"),
                1 + (code / 3 % 3) as usize,
                scheme,
                |_, _| Box::new(Noop),
            ));
            w.connect(prev, op, 0);
            prev = op;
        }
        let mut p = CostParams::default();
        p.source_rows.insert(0, *rows as f64);
        for (i, code) in codes.iter().enumerate() {
            p.selectivity.insert(i, 0.25 + (code % 8) as f64 * 0.25);
        }
        let regions = regions_of(&w);
        if regions.len() != 1 {
            // Chains of pipelined edges are single-region by
            // construction; anything else is outside the claim.
            return true;
        }
        let rows_out = cardinalities(&w, &p);
        let budget = w.ops.len() + *spare as usize;
        let expected = assign_workers(&w, &regions, &rows_out, &p, budget, &HashMap::new());
        let got = arbitrate(
            &[ArbiterJob { workflow: &w, cost: &p, weight: 1.0, fixed: HashMap::new() }],
            budget,
        );
        got[0] == expected
    });
}

/// Seeded multi-tenant action fuzzer: 2–8 concurrent workflows on one
/// service while random submit/cancel/pause/resume/scale/migrate
/// traffic hits them. Invariants: the global budget is **never**
/// exceeded (ledger peak), every admitted workflow reaches a terminal
/// state, and every uncancelled, unerrored workflow produces the exact
/// sequential-run result. `CHAOS_SERVICE=1` (CI matrix) widens the
/// trial count; `CHAOS_SEED` shifts the whole action stream.
#[test]
fn prop_service_fuzzer_budget_never_exceeded_and_all_complete() {
    let base: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(11);
    let trials = if chaos_service_enabled() { 12 } else { 3 };
    for trial in 0..trials {
        service_fuzz_trial(base.wrapping_mul(10_000).wrapping_add(trial));
    }
}

fn service_fuzz_trial(seed: u64) {
    use texera_amber::config::Config;
    use texera_amber::engine::{
        Execution, OpSpec, PlanDelta, Workflow,
    };
    use texera_amber::operators::group_by::{AggKind, GroupByFinal, GroupByPartial};
    use texera_amber::operators::{CollectSink, SinkHandle};
    use texera_amber::service::{EngineService, ServiceConfig, Submission, TenantId};
    use texera_amber::workloads::VecSource;

    const ROWS: usize = 3000;
    const KEYS: i64 = 41;

    // scan → gb_partial → gb_final (blocking) → sink; 4 ops, min 4.
    fn flow() -> (Workflow, SinkHandle) {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, |idx, parts| {
            let rows: Vec<Tuple> = (0..ROWS)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int(i as i64 % KEYS), Value::Int(i as i64)]))
                .collect();
            Box::new(VecSource::new(rows))
        }));
        let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(GroupByPartial::new(0, 1, AggKind::Sum))
        }));
        let fin = w.add(
            OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
                Box::new(GroupByFinal::new(AggKind::Sum))
            })
            .with_blocking(vec![0]),
        );
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(scan, partial, 0);
        w.connect(partial, fin, 0);
        w.connect(fin, sink, 0);
        (w, handle)
    }

    fn sorted(h: &SinkHandle) -> Vec<String> {
        let mut rows: Vec<String> = h.tuples().iter().map(|t| format!("{t:?}")).collect();
        rows.sort_unstable();
        rows
    }

    let mut rng = Rng::new(seed);

    // Sequential reference.
    let (rw, rh) = flow();
    Execution::start(rw, Config::for_tests()).join();
    let reference = sorted(&rh);
    assert!(!reference.is_empty());

    let capacity = 5 + rng.below(8) as usize; // 5..=12 vs min footprint 4
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = capacity;
    // Spill axis: a small service-wide memory budget reaches every job
    // through its tenant's memory share, and a trial-unique spill base
    // lets the post-run sweep assert *this* trial reclaimed all its
    // temp files — including jobs torn down by `cancel`.
    let spill_base = if chaos_spill_enabled() {
        apply_chaos_spill(&mut cfg.engine, seed);
        Some(
            std::env::temp_dir()
                .join(format!("amber-chaos-spill-{}-{seed}", std::process::id())),
        )
    } else {
        None
    };
    let svc = EngineService::start(cfg);

    let n_jobs = 2 + rng.below(7) as usize; // 2..=8
    let mut jobs = Vec::new();
    for _ in 0..n_jobs {
        let (w, h) = flow();
        let mut job_cfg = Config::for_tests();
        if let Some(base) = &spill_base {
            job_cfg.spill_dir = base.to_string_lossy().into_owned();
        }
        let mut sub = Submission::new(TenantId(rng.below(3)), w)
            .with_sink(h.clone())
            .with_config(job_cfg);
        if rng.below(3) == 0 {
            sub = sub.interactive();
        }
        let id = svc.submit(sub).expect("capacity >= min footprint, queue empty");
        jobs.push((id, h));
    }

    // Random control-plane traffic against random jobs.
    for _ in 0..n_jobs * 4 {
        let (id, _) = jobs[rng.below(jobs.len() as u64) as usize];
        match rng.below(6) {
            0 => {
                // Cancel at most one job per trial so the result check
                // still covers most of the fleet.
                if rng.below(4) == 0 {
                    svc.cancel(id);
                }
            }
            1 => {
                svc.pause_job(id);
            }
            2 => {
                svc.resume_job(id);
            }
            3 => {
                svc.scale_job(id, rng.below(4) as usize, 1 + rng.below(3) as usize);
            }
            4 => {
                svc.migrate_job(
                    id,
                    PlanDelta::Replan {
                        workers: vec![
                            (1, 1 + rng.below(2) as usize),
                            (2, 1 + rng.below(2) as usize),
                        ],
                    },
                );
            }
            _ => std::thread::sleep(std::time::Duration::from_millis(1)),
        }
    }

    // Sweep: release any user pauses so every job can finish.
    for (id, _) in &jobs {
        svc.resume_job(*id);
    }

    for (id, h) in jobs {
        let r = svc.wait(id).expect("every admitted job reaches a terminal state");
        assert!(
            r.cancelled || r.error.is_none(),
            "seed {seed}: job {id:?} failed: {:?}",
            r.error
        );
        if !r.cancelled {
            assert_eq!(
                sorted(&h),
                reference,
                "seed {seed}: job {id:?} diverged under service chaos"
            );
        }
    }
    assert!(
        svc.ledger().peak() <= capacity,
        "seed {seed}: budget exceeded: peak {} > {capacity}",
        svc.ledger().peak()
    );
    let s = svc.stats();
    assert_eq!(s.submitted, n_jobs as u64);
    assert_eq!(s.completed + s.failed + s.cancelled, n_jobs as u64);

    if let Some(base) = spill_base {
        // Every job is terminal, so every execution (cancelled ones
        // included) has been dropped and its spill directory removed.
        drop(svc);
        let leaked: Vec<std::path::PathBuf> = std::fs::read_dir(&base)
            .map(|rd| rd.filter_map(|e| e.ok()).map(|e| e.path()).collect())
            .unwrap_or_default();
        assert!(
            leaked.is_empty(),
            "seed {seed}: leaked spill temp files: {leaked:?}"
        );
        let _ = std::fs::remove_dir(&base);
    }
}

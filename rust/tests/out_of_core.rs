//! Out-of-core execution: the memory-pressure equivalence suite.
//!
//! Every stateful operator (hash join build/probe, both group-by
//! layers, sort runs) and the disk-backed `MatStore` must produce
//! **byte-identical** results under any memory budget: runs at 0.5x /
//! 0.25x / 0.1x of the workload's resident state size are compared
//! against the unbounded run's sink multiset, at batch 32 and 1024,
//! under uniform and 90%-hot-key distributions. At 0.1x the suite
//! additionally asserts the operators actually went to disk
//! (`SpillStats::bytes_spilled > 0`) — equivalence proved on the spill
//! path, not vacuously on the resident one.
//!
//! Spilled state must also compose with the interactivity machinery:
//! checkpoint → kill → recover with spill manifests on disk, and scale
//! fences (2→4, 4→2) that re-hash spilled partitions mid-spill. And it
//! must never leak: the cleanup regression tests pin that mid-run
//! drop, service cancel and supervised abort all reclaim the
//! execution's spill temp directory.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::spill::SpillCtx;
use texera_amber::engine::{
    Execution, Fault, FaultPlan, OpSpec, PartitionScheme, WorkerId, Workflow,
};
use texera_amber::maestro::materialize::{MatSource, MatStore};
use texera_amber::metrics::SpillStats;
use texera_amber::operators::basic::MapUdf;
use texera_amber::operators::{
    AggKind, CollectSink, GroupByFinal, GroupByPartial, HashJoin, SinkHandle, SortMerge,
    SortWorker,
};
use texera_amber::service::{EngineService, ServiceConfig, Submission, TenantId, TenantQuota};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::{TupleSource, VecSource};

/// Key distribution shared by every workload: uniform, or 90% of rows
/// on key 0 with the rest striding the key space (hot-key skew — one
/// spill partition takes most of the traffic).
fn key_of(i: usize, keys: i64, hot: bool) -> i64 {
    if hot && i % 10 != 0 {
        0
    } else {
        i as i64 % keys
    }
}

/// Canonical sink multiset (tuples have no `Ord`; debug formatting is
/// injective on `Value` and byte-preserving for floats).
fn sorted_rows(handle: &SinkHandle) -> Vec<String> {
    let mut rows: Vec<String> = handle.tuples().iter().map(|t| format!("{t:?}")).collect();
    rows.sort_unstable();
    rows
}

/// Run one workflow to completion under `budget` bytes and return its
/// canonical sink multiset plus the execution's spill counters. Every
/// run also re-checks the teardown invariant: once the `Execution` is
/// dropped, its spill directory is gone.
fn run(mk: &dyn Fn() -> (Workflow, SinkHandle), budget: u64, batch: usize) -> (Vec<String>, SpillStats) {
    let (w, handle) = mk();
    let cfg = Config {
        batch_size: batch,
        ctrl_check_interval: batch,
        memory_budget_bytes: budget,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    let summary = exec.join();
    assert_eq!(summary.error, None, "budget {budget} batch {batch}: run errored");
    let dir = exec.spill_dir();
    drop(exec);
    if let Some(dir) = dir {
        assert!(!dir.exists(), "budget {budget} batch {batch}: leaked spill dir");
    }
    (sorted_rows(&handle), summary.spill)
}

/// The equivalence matrix for one workload: for each batch size, an
/// unbounded reference run measures the resident-state high water, and
/// runs at 0.5x / 0.25x / 0.1x of it must reproduce the reference
/// multiset exactly — with real spilling asserted at 0.1x.
fn equivalence_suite(name: &str, mk: &dyn Fn() -> (Workflow, SinkHandle)) {
    for batch in [32usize, 1024] {
        let (reference, unbounded) = run(mk, 0, batch);
        assert!(!reference.is_empty(), "{name} batch {batch}: empty reference");
        assert_eq!(
            unbounded.bytes_spilled, 0,
            "{name} batch {batch}: unbounded run must not spill"
        );
        let hw = unbounded.budget_high_water;
        assert!(
            hw > 4096,
            "{name} batch {batch}: resident state too small to exercise budgets ({hw} B)"
        );
        for (frac, budget) in [("0.5x", hw / 2), ("0.25x", hw / 4), ("0.1x", hw / 10)] {
            let (rows, stats) = run(mk, budget, batch);
            assert_eq!(
                rows, reference,
                "{name} batch {batch} budget {frac}: sink multiset diverged"
            );
            assert_eq!(stats.budget_limit, budget);
            if frac == "0.1x" {
                assert!(
                    stats.bytes_spilled > 0,
                    "{name} batch {batch} budget {frac}: never spilled: {stats:?}"
                );
                assert!(
                    stats.bytes_read_back > 0,
                    "{name} batch {batch} budget {frac}: spilled but never read back: {stats:?}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workloads. Operator indices are fixed by construction and documented
// per builder; `scan_cost_ns` adds a per-tuple parse cost so control
// traffic (scale fences, faults, cancels) lands mid-stream.
// ---------------------------------------------------------------------------

/// dim(0) ⨝ scan(1) → join(2) → sink(3); output rows (k, 3k, k, v).
/// Build side: 4 000 dim rows (~160 KB resident hash table).
fn join_flow(hot: bool, scan_cost_ns: u64) -> (Workflow, SinkHandle) {
    const ROWS: usize = 40_000;
    const KEYS: i64 = 4_000;
    let mut w = Workflow::new();
    let dim = w.add(OpSpec::source("dim", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..KEYS)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(3 * k)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..ROWS)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    Tuple::new(vec![
                        Value::Int(key_of(i, KEYS, hot)),
                        Value::Int(i as i64 % 9),
                    ])
                })
                .collect();
            Box::new(VecSource::new(rows))
        },
        move |_, _| Box::new(MapUdf::identity(scan_cost_ns)),
    ));
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0)),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(dim, join, 0);
    w.connect(scan, join, 1);
    w.connect(join, sink, 0);
    (w, handle)
}

/// Ground truth for [`join_flow`]: every probe row joins its key's dim
/// row, so the sink holds (k, 3k, k, v) per probe row.
fn join_expected(hot: bool) -> Vec<String> {
    let mut rows: Vec<String> = (0..40_000)
        .map(|i| {
            let k = key_of(i, 4_000, hot);
            format!(
                "{:?}",
                Tuple::new(vec![
                    Value::Int(k),
                    Value::Int(3 * k),
                    Value::Int(k),
                    Value::Int(i as i64 % 9),
                ])
            )
        })
        .collect();
    rows.sort_unstable();
    rows
}

/// scan(0) → gb_partial(1) → gb_final(2, blocking) → sink(3); sums of
/// v = i mod 7 per key. 6 000 keys, so both layers hold large tables
/// (sums of small integers are exact in f64 — order-independent).
fn group_by_flow(rows: usize, hot: bool, scan_cost_ns: u64) -> (Workflow, SinkHandle) {
    const KEYS: i64 = 6_000;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let data: Vec<Tuple> = (0..rows)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    Tuple::new(vec![
                        Value::Int(key_of(i, KEYS, hot)),
                        Value::Int(i as i64 % 7),
                    ])
                })
                .collect();
            Box::new(VecSource::new(data))
        },
        move |_, _| Box::new(MapUdf::identity(scan_cost_ns)),
    ));
    let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(GroupByPartial::new(0, 1, AggKind::Sum))
    }));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    (w, handle)
}

/// Ground truth for [`group_by_flow`]: (key, Σ v) per distinct key.
fn group_by_expected(rows: usize, hot: bool) -> Vec<(i64, f64)> {
    let mut sums: std::collections::HashMap<i64, f64> = std::collections::HashMap::new();
    for i in 0..rows {
        *sums.entry(key_of(i, 6_000, hot)).or_insert(0.0) += (i % 7) as f64;
    }
    let mut out: Vec<(i64, f64)> = sums.into_iter().collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

fn group_by_result(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut out: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

/// scan(0) → sort(1, range-partitioned, blocking) → merge(2, blocking)
/// → sink(3); rows (k, i). Both sort layers buffer the full stream, so
/// resident state ≈ the whole input.
fn sort_flow(hot: bool) -> (Workflow, SinkHandle) {
    const ROWS: usize = 24_000;
    const KEYS: i64 = 4_000;
    let bounds = vec![Value::Int(KEYS / 2)];
    let b2 = bounds.clone();
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                Tuple::new(vec![Value::Int(key_of(i, KEYS, hot)), Value::Int(i as i64)])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let sort = w.add(
        OpSpec::unary(
            "sort",
            2,
            PartitionScheme::Range { key: 0, bounds },
            move |idx, _| Box::new(SortWorker::new(0, idx as u64, b2.clone())),
        )
        .with_blocking(vec![0]),
    );
    let merge = w.add(
        OpSpec::unary("merge", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(0))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, sort, 0);
    w.connect(sort, merge, 0);
    w.connect(merge, sink, 0);
    (w, handle)
}

// ---------------------------------------------------------------------------
// The equivalence matrix: 3 operators × {uniform, 90%-hot-key}.
// ---------------------------------------------------------------------------

#[test]
fn ooc_join_uniform_keys() {
    equivalence_suite("join/uniform", &|| join_flow(false, 0));
}

#[test]
fn ooc_join_hot_keys() {
    equivalence_suite("join/hot", &|| join_flow(true, 0));
}

#[test]
fn ooc_group_by_uniform_keys() {
    equivalence_suite("group_by/uniform", &|| group_by_flow(40_000, false, 0));
}

#[test]
fn ooc_group_by_hot_keys() {
    equivalence_suite("group_by/hot", &|| group_by_flow(40_000, true, 0));
}

#[test]
fn ooc_sort_uniform_keys() {
    equivalence_suite("sort/uniform", &|| sort_flow(false));
}

#[test]
fn ooc_sort_hot_keys() {
    equivalence_suite("sort/hot", &|| sort_flow(true));
}

// ---------------------------------------------------------------------------
// Disk-backed MatStore: sequential append writer, windowed scan
// readers, logical size invariance, cleanup.
// ---------------------------------------------------------------------------

#[test]
fn ooc_matstore_disk_backed_roundtrip() {
    let input: Vec<Tuple> = (0..20_000)
        .map(|i| Tuple::new(vec![Value::Int(i as i64 % 101), Value::Int(i as i64)]))
        .collect();
    let mut want: Vec<String> = input.iter().map(|t| format!("{t:?}")).collect();
    want.sort_unstable();

    let ctx_with = |budget: u64| {
        SpillCtx::new(&Config { memory_budget_bytes: budget, ..Config::default() })
    };

    // Unbounded run measures the resident footprint.
    let resident = {
        let ctx = ctx_with(0);
        let store = MatStore::new();
        store.attach_spill(&ctx);
        store.append_rows(input.clone());
        assert_eq!(store.spilled_bytes(), 0, "unbounded store must stay resident");
        store.bytes()
    };
    assert!(resident > 4096, "mat footprint too small: {resident} B");

    for batch in [32usize, 1024] {
        for (frac, budget) in
            [("0.5x", resident / 2), ("0.25x", resident / 4), ("0.1x", resident / 10)]
        {
            let ctx = ctx_with(budget);
            let store = MatStore::new();
            store.attach_spill(&ctx);
            for chunk in input.chunks(batch) {
                store.append_rows(chunk.to_vec());
            }
            assert_eq!(store.rows(), input.len());
            assert_eq!(
                store.bytes(),
                resident,
                "batch {batch} {frac}: logical bytes must be budget-independent"
            );
            if frac == "0.1x" {
                assert!(
                    store.spilled_bytes() > 0,
                    "batch {batch} {frac}: store never went to disk"
                );
            }
            // Windowed scan readers, partitioned like MatSource workers:
            // the 2-way union must equal the appended rows exactly.
            let mut got: Vec<String> = Vec::new();
            for idx in 0..2 {
                let mut src = MatSource::new(store.clone(), 2, idx);
                while let Some(t) = src.next_tuple() {
                    got.push(format!("{t:?}"));
                }
            }
            got.sort_unstable();
            assert_eq!(got, want, "batch {batch} {frac}: read-back diverged");

            let dir = ctx.dir_path();
            drop(store);
            drop(ctx);
            if let Some(dir) = dir {
                assert!(!dir.exists(), "batch {batch} {frac}: leaked mat chunks");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Spilled state × interactivity: recovery and scale fences.
// ---------------------------------------------------------------------------

/// Checkpoint → kill → recover with spilled state on disk: a
/// supervised run under a tight budget takes automatic (and one
/// explicit) checkpoints whose manifests reference live spill files,
/// then a worker panic forces recovery to replay them byte-exactly.
#[test]
fn ooc_checkpoint_kill_recover_with_spilled_state() {
    const ROWS: usize = 60_000;
    let (w, handle) = group_by_flow(ROWS, false, 2_000);
    let mut plan = FaultPlan::default();
    // gb_partial worker 0 dies ~30 ms in — well past the first
    // checkpoints, well before EOF.
    plan.push(Fault::panic_at(WorkerId::new(1, 0), 15_000));
    let cfg = Config {
        memory_budget_bytes: 48 * 1024,
        ft_log: true,
        heartbeat_timeout_ms: 150,
        checkpoint_interval_ms: 10,
        recovery_backoff_ms: 5,
        fault_plan: plan,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    std::thread::sleep(Duration::from_millis(8));
    let _ = exec.checkpoint(); // at least one quiesced checkpoint pre-kill

    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let summary = exec.join();
        let dir = exec.spill_dir();
        drop(exec);
        let _ = tx.send((summary, dir));
    });
    let (summary, dir) = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("supervised run did not terminate");
    assert_eq!(summary.error, None, "recovery failed: {:?}", summary.error);
    assert!(summary.supervision.crashes_detected >= 1, "panic was not detected");
    assert!(summary.supervision.recoveries >= 1, "no recovery cycle ran");
    assert!(
        summary.spill.bytes_spilled > 0,
        "state never spilled — recovery did not cover the manifest path: {:?}",
        summary.spill
    );
    assert_eq!(
        group_by_result(&handle),
        group_by_expected(ROWS, false),
        "recovered run diverged from ground truth"
    );
    if let Some(dir) = dir {
        assert!(!dir.exists(), "recovered run leaked its spill dir");
    }
}

/// Scale fences mid-spill on the join: 2→4 then 4→2 while the build
/// table is partially on disk. `ExtractScaleState` must re-hash the
/// spilled partitions across the new worker set without losing or
/// duplicating a row.
#[test]
fn ooc_scale_fence_mid_spill_join() {
    let (w, handle) = join_flow(false, 3_000);
    let cfg = Config {
        memory_budget_bytes: 16 * 1024,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    std::thread::sleep(Duration::from_millis(5));
    assert!(
        exec.scale_operator(2, 4) > Duration::ZERO,
        "2→4 join scale fence refused"
    );
    std::thread::sleep(Duration::from_millis(10));
    assert!(
        exec.scale_operator(2, 2) > Duration::ZERO,
        "4→2 join scale fence refused"
    );
    let summary = exec.join();
    assert_eq!(summary.error, None);
    assert!(
        summary.spill.bytes_spilled > 0,
        "scale fences never crossed spilled state: {:?}",
        summary.spill
    );
    assert_eq!(sorted_rows(&handle), join_expected(false));
    let dir = exec.spill_dir();
    drop(exec);
    if let Some(dir) = dir {
        assert!(!dir.exists(), "scaled run leaked its spill dir");
    }
}

/// Scale fences mid-spill on the blocking group-by final: 2→4 then 4→2
/// while both aggregation layers hold spilled partitions.
#[test]
fn ooc_scale_fence_mid_spill_group_by() {
    const ROWS: usize = 60_000;
    let (w, handle) = group_by_flow(ROWS, false, 2_000);
    let cfg = Config {
        memory_budget_bytes: 32 * 1024,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    std::thread::sleep(Duration::from_millis(5));
    assert!(
        exec.scale_operator(2, 4) > Duration::ZERO,
        "2→4 gb_final scale fence refused"
    );
    std::thread::sleep(Duration::from_millis(10));
    assert!(
        exec.scale_operator(2, 2) > Duration::ZERO,
        "4→2 gb_final scale fence refused"
    );
    let summary = exec.join();
    assert_eq!(summary.error, None);
    assert!(summary.spill.bytes_spilled > 0, "{:?}", summary.spill);
    assert_eq!(group_by_result(&handle), group_by_expected(ROWS, false));
    let dir = exec.spill_dir();
    drop(exec);
    if let Some(dir) = dir {
        assert!(!dir.exists(), "scaled run leaked its spill dir");
    }
}

// ---------------------------------------------------------------------------
// Cleanup regressions: every early-exit path reclaims the spill dir.
// ---------------------------------------------------------------------------

/// Mid-run drop (the `EngineService::cancel` teardown primitive): the
/// spill directory exists while the job spills and is gone the moment
/// the `Execution` is dropped.
#[test]
fn ooc_spill_dir_reclaimed_on_mid_run_drop() {
    let (w, _handle) = join_flow(false, 3_000);
    let cfg = Config {
        memory_budget_bytes: 16 * 1024,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while exec.spill_stats().bytes_spilled == 0 {
        assert!(std::time::Instant::now() < deadline, "join build never spilled");
        std::thread::sleep(Duration::from_millis(1));
    }
    let dir = exec.spill_dir().expect("spilled bytes imply a spill dir");
    assert!(dir.is_dir());
    drop(exec);
    assert!(!dir.exists(), "mid-run drop leaked the spill dir");
}

/// Cancelling a spilling job through the serving layer deletes its
/// spill/mat temp directory (regression: audited over every
/// early-return path in `Execution` teardown and service `cancel`).
#[test]
fn ooc_spill_dir_reclaimed_on_service_cancel() {
    let base = std::env::temp_dir().join(format!("ooc-cancel-{}", std::process::id()));
    let mut svc_cfg = ServiceConfig::for_tests();
    svc_cfg.engine.max_workers = 0;
    let svc = EngineService::start(svc_cfg);

    let (w, handle) = join_flow(false, 3_000);
    let job_cfg = Config {
        memory_budget_bytes: 16 * 1024,
        spill_dir: base.to_string_lossy().into_owned(),
        ..Config::default()
    };
    let id = svc
        .submit(Submission::new(TenantId(1), w).with_sink(handle).with_config(job_cfg))
        .expect("admission");
    std::thread::sleep(Duration::from_millis(20)); // let the build spill
    svc.cancel(id);
    let r = svc.wait(id).expect("cancelled job reaches a terminal state");
    assert!(r.cancelled || r.error.is_none());
    drop(svc);

    let leaked: Vec<std::path::PathBuf> = std::fs::read_dir(&base)
        .map(|rd| rd.filter_map(|e| e.ok()).map(|e| e.path()).collect())
        .unwrap_or_default();
    assert!(leaked.is_empty(), "cancelled job leaked spill files: {leaked:?}");
    let _ = std::fs::remove_dir(&base);
}

/// A worker panic without supervision aborts the execution with a
/// structured error — and the abort path still reclaims the spill dir.
#[test]
fn ooc_spill_dir_reclaimed_on_abort() {
    const ROWS: usize = 60_000;
    let (w, _handle) = group_by_flow(ROWS, false, 1_000);
    let mut plan = FaultPlan::default();
    plan.push(Fault::panic_at(WorkerId::new(1, 0), 10_000));
    let cfg = Config {
        memory_budget_bytes: 16 * 1024,
        fault_plan: plan,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    let summary = exec.join();
    assert!(
        summary.error.is_some(),
        "unsupervised panic must abort with a structured error"
    );
    assert!(
        summary.spill.bytes_spilled > 0,
        "panic landed before any spill: {:?}",
        summary.spill
    );
    let dir = exec.spill_dir();
    drop(exec);
    if let Some(dir) = dir {
        assert!(!dir.exists(), "aborted run leaked its spill dir");
    }
}

// ---------------------------------------------------------------------------
// Tenant memory shares.
// ---------------------------------------------------------------------------

/// `TenantQuota::max_memory_share` arithmetic: a share of an unbounded
/// budget stays unbounded; a share of a bounded one floors but never
/// silently re-unbounds.
#[test]
fn ooc_tenant_memory_share_allowance() {
    let q = TenantQuota { max_memory_share: 0.25, ..TenantQuota::default() };
    assert_eq!(q.memory_allowance(0), 0);
    assert_eq!(q.memory_allowance(100_000), 25_000);
    let tiny = TenantQuota { max_memory_share: 0.000_001, ..TenantQuota::default() };
    assert_eq!(tiny.memory_allowance(100), 1);
    let full = TenantQuota::default();
    assert_eq!(full.memory_allowance(100_000), 100_000);
}

/// End-to-end share enforcement: the job's own config is *unbounded*,
/// so the only way spill files can appear is the service capping the
/// job at its tenant's share of the service-wide budget. The job must
/// still produce the exact result and reclaim its temp files.
#[test]
fn ooc_tenant_memory_share_caps_job_budget() {
    let base = std::env::temp_dir().join(format!("ooc-share-{}", std::process::id()));
    let mut svc_cfg = ServiceConfig::for_tests();
    svc_cfg.engine.max_workers = 0;
    svc_cfg.engine.memory_budget_bytes = 64 * 1024;
    svc_cfg.quotas.insert(
        1,
        TenantQuota { max_memory_share: 0.25, ..TenantQuota::default() },
    );
    let svc = EngineService::start(svc_cfg);

    let (w, handle) = join_flow(false, 3_000);
    let job_cfg = Config {
        spill_dir: base.to_string_lossy().into_owned(),
        ..Config::default()
    };
    let id = svc
        .submit(
            Submission::new(TenantId(1), w)
                .with_sink(handle.clone())
                .with_config(job_cfg),
        )
        .expect("admission");

    // 0.25 × 64 KiB = 16 KiB against a ~160 KB build table: spill
    // files must appear under the job's temp base while it runs.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let spilling = std::fs::read_dir(&base)
            .map(|rd| rd.count() > 0)
            .unwrap_or(false);
        if spilling {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "tenant share never forced the job to spill"
        );
        std::thread::sleep(Duration::from_millis(1));
    }

    let r = svc.wait(id).expect("job reaches a terminal state");
    assert!(!r.cancelled);
    assert_eq!(r.error, None);
    assert_eq!(sorted_rows(&handle), join_expected(false));
    drop(svc);

    let leaked: Vec<std::path::PathBuf> = std::fs::read_dir(&base)
        .map(|rd| rd.filter_map(|e| e.ok()).map(|e| e.path()).collect())
        .unwrap_or_default();
    assert!(leaked.is_empty(), "share-capped job leaked spill files: {leaked:?}");
    let _ = std::fs::remove_dir(&base);
}

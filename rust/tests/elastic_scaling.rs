//! Elastic-scaling integration tests (engine::scale): changing an
//! operator's parallelism mid-run must not change the result.
//!
//! A scan→filter→group-by→sink workflow is scaled at a random mid-run
//! point (seeded; override with `CHAOS_SEED` for the CI matrix). The
//! sink multiset must be exactly the unscaled run's — group-by sums
//! over integer-valued floats, so equality is byte-exact — and the
//! pause-migrate-resume epoch must stay under one second at batch
//! size 1024.

use std::time::Duration;
use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::operators::basic::{Cmp, Filter, MapUdf};
use texera_amber::operators::group_by::{AggKind, GroupByFinal};
use texera_amber::operators::{CollectSink, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::util::Rng;
use texera_amber::workloads::VecSource;

const ROWS: usize = 600_000;
const KEYS: i64 = 97;

fn seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

/// (key, value) rows: key cycles over `KEYS`, value over 0..10.
fn row(i: usize) -> Tuple {
    Tuple::new(vec![
        Value::Int(i as i64 % KEYS),
        Value::Int(i as i64 % 10),
    ])
}

/// scan(2) → filter(2, drop value==0) → group-by-sum(`gb_workers`,
/// hash by key) → sink(1). Returns (workflow, group-by op, sink).
fn build(gb_workers: usize) -> (Workflow, usize, SinkHandle) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..ROWS).skip(idx).step_by(parts).map(row).collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| {
            let mut f = Filter::new(1, Cmp::Ne, Value::Int(0));
            // A little artificial predicate cost keeps the run long
            // enough that the mid-run scale point is genuinely mid-run.
            f.cost_ns = 800;
            Box::new(f)
        },
    ));
    let gb = w.add(
        OpSpec::unary(
            "group_by",
            gb_workers,
            PartitionScheme::Hash { key: 0 },
            |_, _| Box::new(GroupByFinal::new(AggKind::Sum)),
        )
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, filter, 0);
    w.connect(filter, gb, 0);
    w.connect(gb, sink, 0);
    (w, gb, handle)
}

fn config() -> Config {
    Config {
        batch_size: 1024,
        ctrl_check_interval: 1024,
        ..Config::default()
    }
}

/// Canonical sorted (key, sum) result list.
fn result_of(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut out: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

fn unscaled_reference(gb_workers: usize) -> Vec<(i64, f64)> {
    let (w, _, handle) = build(gb_workers);
    Execution::start(w, config()).join();
    result_of(&handle)
}

/// Run with one mid-run scale of the group-by; returns (result, fence).
fn scaled_run(from: usize, to: usize, delay_ms: u64) -> (Vec<(i64, f64)>, Duration) {
    let (w, gb, handle) = build(from);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(delay_ms));
    let fence = exec.scale_operator(gb, to);
    exec.join();
    (result_of(&handle), fence)
}

#[test]
fn scale_up_2_to_4_exact_and_subsecond() {
    let mut rng = Rng::new(seed());
    let reference = unscaled_reference(2);
    // Sanity: the reference itself matches a direct computation.
    let mut expect = std::collections::HashMap::new();
    for i in 0..ROWS {
        let (k, v) = (i as i64 % KEYS, i as i64 % 10);
        if v != 0 {
            *expect.entry(k).or_insert(0.0) += v as f64;
        }
    }
    assert_eq!(reference.len(), expect.len());
    for (k, s) in &reference {
        assert_eq!(expect[k], *s, "reference wrong for key {k}");
    }

    let delay = 20 + rng.below(100);
    let (scaled, fence) = scaled_run(2, 4, delay);
    assert!(
        fence > Duration::ZERO,
        "scale was refused — run finished before the scale point?"
    );
    assert!(
        fence < Duration::from_secs(1),
        "fenced epoch took {fence:?} (≥1s) at batch size 1024"
    );
    assert_eq!(scaled, reference, "2→4 scale changed the sink multiset");
}

#[test]
fn scale_down_4_to_2_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0x5eed);
    let reference = unscaled_reference(4);
    let delay = 20 + rng.below(100);
    let (scaled, fence) = scaled_run(4, 2, delay);
    assert!(
        fence > Duration::ZERO,
        "scale was refused — run finished before the scale point?"
    );
    assert!(fence < Duration::from_secs(1), "fenced epoch took {fence:?}");
    assert_eq!(scaled, reference, "4→2 scale changed the sink multiset");
}

#[test]
fn repeated_scales_up_and_down_stay_exact() {
    let mut rng = Rng::new(seed() ^ 0xe1a5);
    let reference = unscaled_reference(2);
    let (w, gb, handle) = build(2);
    let exec = Execution::start(w, config());
    // 2→4→3→1: every hop re-hashes the accumulated sums.
    for to in [4usize, 3, 1] {
        std::thread::sleep(Duration::from_millis(10 + rng.below(40)));
        exec.scale_operator(gb, to);
    }
    exec.join();
    assert_eq!(
        result_of(&handle),
        reference,
        "repeated scaling changed the sink multiset"
    );
}

#[test]
fn scaling_refuses_bad_requests() {
    // Sources are no longer structurally refused (universal
    // elasticity; see tests/elastic_universal.rs) — only genuinely
    // invalid requests are.
    let (w, gb, handle) = build(2);
    let exec = Execution::start(w, config());
    assert_eq!(exec.scale_operator(99, 4), Duration::ZERO, "scaled unknown op");
    assert_eq!(exec.scale_operator(gb, 0), Duration::ZERO, "scaled to zero");
    assert_eq!(exec.scale_operator(gb, 2), Duration::ZERO, "no-op scale ran");
    exec.join();
    assert!(handle.total() > 0);
}

#[test]
fn autoscale_plugin_scales_up_overloaded_operator() {
    use texera_amber::engine::AutoscalePlugin;
    use texera_amber::engine::WorkerId;

    // A fast scan floods a 1-worker latency-bound operator: the queue
    // stays high, the plugin doubles the workers, and the run still
    // produces every tuple exactly once.
    let rows = 30_000usize;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(data))
    }));
    let udf = w.add(OpSpec::unary(
        "udf",
        1,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(MapUdf::identity(20_000)),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, udf, 0);
    w.connect(udf, sink, 0);
    let cfg = Config {
        batch_size: 64,
        autoscale_high_queue: 64.0,
        autoscale_sustain_ticks: 3,
        ..Config::default()
    };
    let plugin = AutoscalePlugin::new(udf, 1, 4);
    let decisions = plugin.decisions();
    let exec = Execution::start_with_plugin(w, cfg, Box::new(plugin));
    let summary = exec.join();
    assert_eq!(handle.total() as usize, rows, "autoscaled run lost tuples");
    assert!(
        !decisions.lock().unwrap().is_empty(),
        "autoscale never triggered on a saturated operator"
    );
    assert!(
        summary
            .worker_stats
            .iter()
            .any(|(id, _)| *id == WorkerId::new(udf, 1)),
        "no scaled-up worker reported stats"
    );
}

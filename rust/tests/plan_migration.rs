//! Live plan migration (engine::migrate): each delta kind applied
//! mid-run must leave the sink multiset byte-identical to an
//! unmigrated run; an interrupted fence aborts with state fully
//! restored; fences stay sub-second at batch 1024; and recovery from a
//! checkpoint taken before a migration replays exactly — including the
//! fence-aware replay-position remap.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{
    Execution, OpSpec, PartitionScheme, PlanDelta, Workflow,
};
use texera_amber::operators::basic::{Cmp, Filter, MapUdf};
use texera_amber::operators::enrich::{Enrich, DICT, EVENT};
use texera_amber::operators::{CollectSink, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

const ROWS: usize = 80_000;
const KEYS: i64 = 37;

/// scan(2, slow) → filter(2, RR) → sink(1); rows `(i % KEYS, i % 7)`,
/// filter drops `v == 3`. The scan's per-tuple cost keeps the run
/// alive long enough that mid-run deltas land mid-stream.
fn stateless_wf(handle: SinkHandle) -> (Workflow, usize, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..ROWS)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    Tuple::new(vec![Value::Int(i as i64 % KEYS), Value::Int(i as i64 % 7)])
                })
                .collect();
            Box::new(VecSource::new(rows))
        },
        |_, _| Box::new(MapUdf::identity(1500)),
    ));
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(Filter::new(1, Cmp::Ne, Value::Int(3))),
    ));
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    (w, scan, filter)
}

fn expect_stateless() -> Vec<(i64, i64)> {
    let mut rows: Vec<(i64, i64)> = (0..ROWS)
        .map(|i| (i as i64 % KEYS, i as i64 % 7))
        .filter(|&(_, v)| v != 3)
        .collect();
    rows.sort_unstable();
    rows
}

fn collect_pairs(handle: &SinkHandle) -> Vec<(i64, i64)> {
    let mut got: Vec<(i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_int().unwrap()))
        .collect();
    got.sort_unstable();
    got
}

#[test]
fn repartition_applies_mid_run_byte_exact() {
    for batch_size in [32usize, 256, 1024] {
        let handle = SinkHandle::new(0);
        let (w, _scan, filter) = stateless_wf(handle.clone());
        let exec = Execution::start(w, Config { batch_size, ..Config::default() });
        std::thread::sleep(Duration::from_millis(10));
        // RR → Hash: the whole parked stream re-routes by key.
        let o1 = exec.migrate(PlanDelta::Repartition {
            op: filter,
            port: 0,
            scheme: PartitionScheme::Hash { key: 0 },
        });
        assert!(o1.applied, "batch {batch_size}: hash swap refused: {:?}", o1.steps);
        std::thread::sleep(Duration::from_millis(10));
        // Hash → Range with *empty* bounds: the planner derives bounds
        // from the tuples parked in the fence.
        let o2 = exec.migrate(PlanDelta::Repartition {
            op: filter,
            port: 0,
            scheme: PartitionScheme::Range { key: 0, bounds: Vec::new() },
        });
        assert!(o2.applied, "batch {batch_size}: range swap refused: {:?}", o2.steps);
        exec.join();
        assert_eq!(
            collect_pairs(&handle),
            expect_stateless(),
            "batch {batch_size}: multiset differs after repartition"
        );
    }
}

#[test]
fn mat_insert_applies_mid_run_byte_exact() {
    for batch_size in [32usize, 256, 1024] {
        let handle = SinkHandle::new(0);
        let (w, scan, filter) = stateless_wf(handle.clone());
        let exec = Execution::start(w, Config { batch_size, ..Config::default() });
        std::thread::sleep(Duration::from_millis(10));
        let o = exec.migrate(PlanDelta::InsertMat { from: scan, to: filter, to_port: 0 });
        assert!(o.applied, "batch {batch_size}: insert refused: {:?}", o.steps);
        // The reader stays dormant until the writer completes; the run
        // must still drain end-to-end with identical results.
        exec.join();
        assert_eq!(
            collect_pairs(&handle),
            expect_stateless(),
            "batch {batch_size}: multiset differs after mat insert"
        );
    }
}

#[test]
fn mat_insert_then_remove_mid_run_byte_exact() {
    for batch_size in [32usize, 256, 1024] {
        let handle = SinkHandle::new(0);
        let (w, scan, filter) = stateless_wf(handle.clone());
        let exec = Execution::start(w, Config { batch_size, ..Config::default() });
        std::thread::sleep(Duration::from_millis(8));
        let ins = exec.migrate(PlanDelta::InsertMat { from: scan, to: filter, to_port: 0 });
        assert!(ins.applied, "batch {batch_size}: insert refused: {:?}", ins.steps);
        std::thread::sleep(Duration::from_millis(8));
        // Undo while the writer is still live: the store contents and
        // the writer's unflushed tail re-enter the restored edge.
        let rem = exec.migrate(PlanDelta::RemoveMat { from: scan, to: filter, to_port: 0 });
        assert!(rem.applied, "batch {batch_size}: remove refused: {:?}", rem.steps);
        exec.join();
        assert_eq!(
            collect_pairs(&handle),
            expect_stateless(),
            "batch {batch_size}: multiset differs after mat insert+remove"
        );
    }
}

#[test]
fn replan_applies_mid_run_byte_exact() {
    for batch_size in [32usize, 256, 1024] {
        let handle = SinkHandle::new(0);
        let (w, scan, filter) = stateless_wf(handle.clone());
        let exec = Execution::start(w, Config { batch_size, ..Config::default() });
        std::thread::sleep(Duration::from_millis(10));
        let o = exec.migrate(PlanDelta::Replan { workers: vec![(scan, 3), (filter, 3)] });
        assert!(o.applied, "batch {batch_size}: replan refused: {:?}", o.steps);
        assert_eq!(o.steps.len(), 2, "one fenced step per re-planned operator");
        exec.join();
        assert_eq!(
            collect_pairs(&handle),
            expect_stateless(),
            "batch {batch_size}: multiset differs after replan"
        );
    }
}

/// Every delta kind in sequence at batch 1024 (the worst buffering
/// regime): each step's fence must stay sub-second, and the end result
/// byte-exact.
#[test]
fn fences_stay_sub_second_at_batch_1024() {
    let handle = SinkHandle::new(0);
    let (w, scan, filter) = stateless_wf(handle.clone());
    let exec = Execution::start(w, Config { batch_size: 1024, ..Config::default() });
    std::thread::sleep(Duration::from_millis(5));
    let outcomes = vec![
        exec.migrate(PlanDelta::Repartition {
            op: filter,
            port: 0,
            scheme: PartitionScheme::Hash { key: 0 },
        }),
        exec.migrate(PlanDelta::InsertMat { from: scan, to: filter, to_port: 0 }),
        exec.migrate(PlanDelta::RemoveMat { from: scan, to: filter, to_port: 0 }),
        exec.migrate(PlanDelta::Replan { workers: vec![(filter, 3)] }),
    ];
    exec.join();
    for o in &outcomes {
        assert!(o.applied, "delta refused: {:?}", o.steps);
        for s in &o.steps {
            assert!(
                s.fence < Duration::from_secs(1),
                "fence of '{}' took {:?}",
                s.desc,
                s.fence
            );
        }
    }
    assert_eq!(collect_pairs(&handle), expect_stateless());
}

/// Repartitioning a *stateful* multi-worker operator would separate
/// its keyed state shards from the new routing, so the fence must
/// abort-and-restore: the delta reports unapplied, every surrendered
/// shard returns to its owner, and the run finishes byte-exact.
#[test]
fn repartition_of_stateful_operator_aborts_and_restores() {
    const N: usize = 60_000;
    const K: i64 = 23;
    let mut w = Workflow::new();
    let dict = w.add(OpSpec::source("dict", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..K)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(100 + k)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let scan = w.add(OpSpec::source_with_op(
        "scan",
        2,
        move |idx, parts| {
            let rows: Vec<Tuple> = (0..N)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int(i as i64 % K), Value::Int(i as i64 % 9)]))
                .collect();
            Box::new(VecSource::new(rows))
        },
        |_, _| Box::new(MapUdf::identity(1500)),
    ));
    let enrich = w.add(OpSpec::binary(
        "enrich",
        2,
        [PartitionScheme::Broadcast, PartitionScheme::Hash { key: 0 }],
        vec![DICT],
        |_, _| Box::new(Enrich::new()),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h.clone()))
    }));
    w.connect(dict, enrich, DICT);
    w.connect(scan, enrich, EVENT);
    w.connect(enrich, sink, 0);

    let exec = Execution::start(w, Config::default());
    // Wait until the enrich workers demonstrably hold state (dict rows
    // and/or per-key counts) but the run is still in flight.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let processed: u64 = exec
            .stats()
            .iter()
            .filter(|(id, _)| id.op == enrich)
            .map(|(_, s)| s.processed)
            .sum();
        if processed > 0 || std::time::Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let o = exec.migrate(PlanDelta::Repartition {
        op: enrich,
        port: EVENT,
        scheme: PartitionScheme::RoundRobin,
    });
    assert!(
        !o.applied,
        "stateful repartition must abort-and-restore, got {:?}",
        o.steps
    );
    assert!(!o.rolled_back, "single refused step has no prefix to roll back");
    exec.join();

    // Byte-exact despite the aborted fence: enriched events plus one
    // summary row per key.
    let mut expect: Vec<(i64, i64, i64)> = (0..N)
        .map(|i| {
            let (k, v) = (i as i64 % K, i as i64 % 9);
            (k, v + 100 + k, 1)
        })
        .collect();
    for k in 0..K {
        let cnt = (0..N).filter(|&i| i as i64 % K == k).count() as i64;
        expect.push((k, cnt, -1));
    }
    expect.sort_unstable();
    let mut got: Vec<(i64, i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| {
            (
                t.get(0).as_int().unwrap(),
                t.get(1).as_int().unwrap(),
                t.get(2).as_int().unwrap(),
            )
        })
        .collect();
    got.sort_unstable();
    assert_eq!(got, expect, "results distorted by the aborted fence");
}

/// A checkpoint taken *before* a migration recovers exactly: migration
/// control traffic is not logged (the fence re-injects state
/// in-place), so replay re-runs the original plan from the snapshot —
/// results must match both the migrated run and the ground truth.
#[test]
fn checkpoint_before_migration_recovers_exact() {
    let cfg = Config { ft_log: true, ..Config::default() };
    let handle = SinkHandle::new(0);
    let (w, _scan, filter) = stateless_wf(handle.clone());
    let exec = Execution::start(w, cfg.clone());
    std::thread::sleep(Duration::from_millis(8));
    let checkpoint = exec.checkpoint();
    assert!(!checkpoint.workers.is_empty());
    std::thread::sleep(Duration::from_millis(5));
    let o = exec.migrate(PlanDelta::Repartition {
        op: filter,
        port: 0,
        scheme: PartitionScheme::Hash { key: 0 },
    });
    assert!(o.applied, "migration refused: {:?}", o.steps);
    let log = exec.take_replay_log();
    exec.join();
    assert_eq!(collect_pairs(&handle), expect_stateless(), "migrated run differs");

    // Recover from the pre-migration checkpoint with the *original*
    // workflow: byte-exact completion.
    let handle2 = SinkHandle::new(0);
    let (w2, _, _) = stateless_wf(handle2.clone());
    let recovered = Execution::recover(w2, cfg, checkpoint, log);
    recovered.join();
    assert_eq!(
        collect_pairs(&handle2),
        expect_stateless(),
        "recovery across the migration epoch differs"
    );
}

/// Fence-aware replay remap regression: a logged control record whose
/// replay position points *past* the consolidation window must still
/// apply at the exact same tuple after a migration fence renumbered
/// the worker's parked stream. Without the remap the record applies
/// off-by-N batches and the result multiset shifts.
#[test]
fn replay_position_survives_fence_consolidation() {
    const N: usize = 16_384;
    let cfg = Config {
        batch_size: 16,
        ctrl_check_interval: 16,
        data_queue_cap: 2048,
        ft_log: true,
        ..Config::default()
    };
    let build = |handle: SinkHandle| -> (Workflow, usize) {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 1, move |idx, parts| {
            let rows: Vec<Tuple> = (0..N)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int(i as i64 % 7)]))
                .collect();
            Box::new(VecSource::new(rows))
        }));
        let filter = w.add(OpSpec::unary(
            "filter",
            1,
            PartitionScheme::RoundRobin,
            |_, _| {
                let mut f = Filter::new(1, Cmp::Ne, Value::Int(3));
                f.cost_ns = 2000; // keep a deep parked queue behind the fence
                Box::new(f)
            },
        ));
        let h = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h.clone()))
        }));
        w.connect(scan, filter, 0);
        w.connect(filter, sink, 0);
        (w, filter)
    };

    // Run A (reference): checkpoint early, then switch the filter
    // constant mid-stream — the log records the patch at a deep replay
    // position, far beyond any single batch.
    let handle_a = SinkHandle::new(0);
    let (wa, filter) = build(handle_a.clone());
    let exec_a = Execution::start(wa, cfg.clone());
    std::thread::sleep(Duration::from_millis(3));
    let checkpoint = exec_a.checkpoint();
    std::thread::sleep(Duration::from_millis(12));
    exec_a.modify_operator(filter, "constant", "5");
    exec_a.join();
    let log = exec_a.take_replay_log();
    assert!(
        log.iter().any(|r| format!("{:?}", r.ctrl).contains("ModifyOperator")),
        "patch was not logged"
    );
    let reference = collect_pairs(&handle_a);

    // Run B: recover from the checkpoint (the patch is now a parked
    // replay record), then immediately repartition the filter's input.
    // The fence consolidates the whole parked stream into one batch —
    // renumbering every message the record's position referenced — and
    // the worker remaps the position. Byte-exact ⇔ the remap is exact.
    let handle_b = SinkHandle::new(0);
    let (wb, filter_b) = build(handle_b.clone());
    let exec_b = Execution::recover(wb, cfg, checkpoint, log);
    std::thread::sleep(Duration::from_millis(2));
    let o = exec_b.migrate(PlanDelta::Repartition {
        op: filter_b,
        port: 0,
        scheme: PartitionScheme::Hash { key: 0 },
    });
    assert!(o.applied, "mid-replay repartition refused: {:?}", o.steps);
    exec_b.join();
    assert_eq!(
        collect_pairs(&handle_b),
        reference,
        "replay position drifted across the migration fence"
    );
}

//! Fault tolerance (§2.6): quiesced checkpoints + control-replay log —
//! crash, recover, verify results and post-control state equivalence.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, WorkerId, Workflow};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{AggKind, CollectSink, GroupByFinal, GroupByPartial, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// scan → filter → group-by(count per key) → sink; deterministic input.
fn wf(total: usize, handle: SinkHandle) -> Workflow {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(8))) // keep 80%
    }));
    let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(GroupByPartial::new(1, 0, AggKind::Count))
    }));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    w
}

fn expected_counts(total: usize) -> Vec<(i64, f64)> {
    // keys 0..7 kept; each appears total/10 times.
    (0..8).map(|k| (k, (total / 10) as f64)).collect()
}

fn result_counts(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut rows: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    rows.sort_by_key(|(k, _)| *k);
    rows
}

#[test]
fn checkpoint_and_recover_mid_run() {
    let total = 200_000;
    let cfg = Config { ft_log: true, ..Config::default() };
    let handle = SinkHandle::new(0);
    let exec = Execution::start(wf(total, handle.clone()), cfg.clone());
    std::thread::sleep(Duration::from_millis(30));
    // Quiesced checkpoint mid-run.
    let checkpoint = exec.checkpoint();
    assert!(!checkpoint.workers.is_empty());
    std::thread::sleep(Duration::from_millis(10));
    // Simulate a machine failure: kill one filter worker's partition,
    // then abandon the execution entirely and recover from the
    // checkpoint.
    exec.crash_workers(vec![WorkerId::new(1, 0)]);
    let log = exec.take_replay_log();
    drop(exec); // tear down the damaged execution

    let handle2 = SinkHandle::new(0);
    let recovered = Execution::recover(wf(total, handle2.clone()), cfg, checkpoint, log);
    recovered.join();
    assert_eq!(result_counts(&handle2), expected_counts(total));
}

#[test]
fn recovery_from_scratchless_checkpoint_is_exact() {
    // Checkpoint immediately (trivial state), recover, verify equal
    // results — the recovery path itself must not distort anything.
    let total = 50_000;
    let cfg = Config { ft_log: true, ..Config::default() };
    let handle = SinkHandle::new(0);
    let exec = Execution::start(wf(total, handle.clone()), cfg.clone());
    let checkpoint = exec.checkpoint(); // likely very early
    exec.crash_workers(vec![WorkerId::new(0, 0), WorkerId::new(0, 1)]);
    let log = exec.take_replay_log();
    drop(exec);
    let handle2 = SinkHandle::new(0);
    let recovered = Execution::recover(wf(total, handle2.clone()), cfg, checkpoint, log);
    recovered.join();
    assert_eq!(result_counts(&handle2), expected_counts(total));
}

#[test]
fn paused_state_recovers_via_control_replay() {
    // §2.7.8: pause the workflow, crash, recover — the recreated
    // workers replay the logged Pause at the same stream position and
    // the workflow is paused again after recovery.
    let total = 400_000;
    let cfg = Config { ft_log: true, ..Config::default() };
    let handle = SinkHandle::new(0);
    let exec = Execution::start(wf(total, handle.clone()), cfg.clone());
    std::thread::sleep(Duration::from_millis(20));
    let checkpoint = exec.checkpoint();
    std::thread::sleep(Duration::from_millis(10));
    exec.pause(); // logged control message after the checkpoint
    let log = exec.take_replay_log();
    assert!(!log.is_empty(), "pause was not logged");
    drop(exec);

    let handle2 = SinkHandle::new(0);
    let recovered = Execution::recover(wf(total, handle2.clone()), cfg, checkpoint, log);
    // The recovered execution recomputes up to the replay point, where
    // the logged Pause re-applies and progress stops. Poll until the
    // processed count is stable across a 300 ms window.
    let sample = || -> u64 {
        recovered.stats().iter().map(|(_, s)| s.processed).sum()
    };
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let mut prev = sample();
    let mut stable = false;
    while std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(300));
        let cur = sample();
        if cur == prev && cur > 0 {
            stable = true;
            break;
        }
        prev = cur;
    }
    assert!(stable, "workflow never quiesced after replayed Pause");
    // Paused, not completed: at completion the summed processed count
    // exceeds the scan volume (scan + filter + group-by layers all
    // count); the paused total must stay below it.
    assert!(prev < total as u64, "paused total {prev} looks like a completed run");
    // Resume → completes with exact results.
    recovered.resume();
    recovered.join();
    assert_eq!(result_counts(&handle2), expected_counts(total));
}

#[test]
fn replay_log_cleared_by_checkpoint() {
    let total = 200_000;
    let cfg = Config { ft_log: true, ..Config::default() };
    let handle = SinkHandle::new(0);
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    std::thread::sleep(Duration::from_millis(10));
    exec.pause();
    exec.resume();
    assert!(!exec.take_replay_log().is_empty());
    // A checkpoint absorbs prior control effects into state (§2.6.2).
    // The only records allowed afterwards are the checkpoint's own
    // trailing Resume broadcast (post-checkpoint control *should* be
    // logged — it happened after the snapshot).
    let _cp = exec.checkpoint();
    let residual = exec.take_replay_log();
    assert!(
        residual.iter().all(|r| matches!(
            r.ctrl,
            texera_amber::engine::ControlMessage::Resume
        )),
        "non-Resume records survived the checkpoint: {residual:?}"
    );
    exec.join();
}

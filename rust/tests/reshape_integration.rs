//! Reshape end-to-end: skewed tweet-join workloads through the engine
//! with the Reshape plugin, verifying detection, two-phase transfer,
//! load balancing, and the result-awareness property (observed CA:AZ
//! ratio approaches the true ratio while running).

use std::sync::Arc;
use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::metrics::LoadBalanceRatio;
use texera_amber::operators::{
    CollectSink, CountByKeySink, HashJoin, SinkHandle, SortMerge, SortWorker,
};
use texera_amber::reshape::baselines::FluxPlugin;
use texera_amber::reshape::{Approach, ReshapePlugin};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::tweets::{self, TweetSource};
use texera_amber::workloads::{TupleSource, VecSource};

/// W1-of-Ch.3-style workflow: tweets ⋈ slang on location, counting
/// join outputs per location at the sink.
fn w1(total_tweets: usize, join_workers: usize) -> (Workflow, SinkHandle, usize) {
    let mut w = Workflow::new();
    let slang: Arc<Vec<Tuple>> = Arc::new(tweets::slang_table());
    let s2 = slang.clone();
    let build_scan = w.add(OpSpec::source("slang_scan", 1, move |idx, parts| {
        let rows: Vec<Tuple> = s2
            .iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t.clone())
            .collect();
        Box::new(VecSource::new(rows)) as Box<dyn TupleSource>
    }));
    let tweet_scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total_tweets, parts, idx, 0xBEE5)) as Box<dyn TupleSource>
    }));
    let join = w.add(OpSpec::binary(
        "join",
        join_workers,
        [
            PartitionScheme::Hash { key: 0 },                  // slang.location
            PartitionScheme::Hash { key: tweets::F_LOCATION }, // tweet.location
        ],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, tweets::F_LOCATION)),
    ));
    let handle = SinkHandle::new(tweets::NUM_STATES);
    let h2 = handle.clone();
    // Join output = slang(2 cols) ++ tweet(6 cols); location is field 3.
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h2.clone(), 2 + tweets::F_LOCATION))
    }));
    w.connect(build_scan, join, 0);
    w.connect(tweet_scan, join, 1);
    w.connect(join, sink, 0);
    (w, handle, join)
}

/// The join worker that owns a location key under hash partitioning.
fn worker_of(location: usize, workers: usize) -> usize {
    (Value::Int(location as i64).stable_hash() % workers as u64) as usize
}

/// Pins the workload invariant `tweets::WV` encodes: the small WV key
/// co-locates with the CA hot key at the experiments' 8-worker
/// parallelism (§3.7.4 relies on a small key sharing the skewed
/// worker), and the monitored keys stay on distinct workers. The
/// constant is hash-dependent — anyone changing `Value::stable_hash`
/// must re-derive it, and this test is what tells them.
#[test]
fn wv_co_locates_with_ca_and_monitored_keys_stay_distinct() {
    assert_eq!(
        worker_of(tweets::WV, 8),
        worker_of(tweets::CA, 8),
        "tweets::WV must share CA's worker at 8-way parallelism; \
         re-derive the WV constant for the current stable_hash"
    );
    let ca = worker_of(tweets::CA, 8);
    for (name, key) in [("AZ", tweets::AZ), ("IL", tweets::IL), ("TX", tweets::TX)] {
        assert_ne!(
            worker_of(key, 8),
            ca,
            "{name} unexpectedly landed on CA's worker; the ratio/skew \
             experiments assume the monitored keys are on distinct workers"
        );
    }
}

fn reshape_cfg() -> Config {
    Config {
        batch_size: 64,
        data_queue_cap: 16, // small queues → join is the bottleneck
        reshape_eta: 100.0,
        reshape_tau: 100.0,
        reshape_metric_period_ms: 10,
        ..Config::default()
    }
}

#[test]
fn detects_and_mitigates_ca_skew() {
    let workers = 8;
    let (w, _handle, join) = w1(120_000, workers);
    let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
    let report = plugin.report();
    let exec = Execution::start_with_plugin(w, reshape_cfg(), Box::new(plugin));
    let summary = exec.join();

    let rep = report.lock().unwrap();
    assert!(
        !rep.mitigations.is_empty(),
        "CA-dominated worker never detected as skewed"
    );
    // The mitigated worker must be the one owning the CA key.
    let ca_worker = worker_of(tweets::CA, workers);
    assert!(
        rep.mitigations.iter().any(|(_, s, _)| *s == ca_worker),
        "expected worker {ca_worker} (CA) in {:?}",
        rep.mitigations
    );
    // State was replicated before routing changed (Fig. 3.2 order).
    assert!(!rep.transfers.is_empty(), "no state transfer happened");
    // Phase 2 engaged.
    assert!(!rep.phase2.is_empty(), "never reached the rebalance phase");
    // All 120k tweets joined (no loss/duplication through mitigation).
    assert_eq!(summary.produced(join), 120_000);
}

#[test]
fn mitigation_improves_load_balance_vs_unmitigated() {
    let workers = 8;
    let run = |mitigate: bool| -> f64 {
        let (w, _handle, join) = w1(100_000, workers);
        let cfg = reshape_cfg();
        let (exec, report) = if mitigate {
            let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
            let rep = plugin.report();
            (Execution::start_with_plugin(w, cfg, Box::new(plugin)), Some(rep))
        } else {
            (Execution::start(w, cfg), None)
        };
        let summary = exec.join();
        // Average load-balancing ratio (§3.7.4) for (CA worker, its
        // helper or the least-loaded worker).
        let ca_worker = worker_of(tweets::CA, workers);
        let helper = report
            .and_then(|r| {
                let rep = r.lock().unwrap();
                rep.mitigations
                    .iter()
                    .find(|(_, s, _)| *s == ca_worker)
                    .map(|(_, _, h)| h[0])
            })
            .unwrap_or_else(|| {
                // Unmitigated: compare against the least-loaded worker.
                (0..workers)
                    .filter(|&i| i != ca_worker)
                    .min_by_key(|&i| {
                        summary
                            .worker_stats
                            .iter()
                            .find(|(id, _)| id.op == join && id.idx == i)
                            .map(|(_, s)| s.processed)
                            .unwrap_or(0)
                    })
                    .unwrap()
            });
        let get = |idx: usize| {
            summary
                .worker_stats
                .iter()
                .find(|(id, _)| id.op == join && id.idx == idx)
                .map(|(_, s)| s.processed as f64)
                .unwrap_or(0.0)
        };
        let mut lbr = LoadBalanceRatio::default();
        lbr.observe(get(ca_worker), get(helper));
        lbr.average()
    };
    let with = run(true);
    let without = run(false);
    assert!(
        with > without * 1.5,
        "mitigated balance {with:.3} not clearly better than unmitigated {without:.3}"
    );
    assert!(with > 0.4, "final balance too poor: {with:.3}");
}

#[test]
fn observed_ratio_approaches_actual_with_mitigation() {
    // The Fig. 3.16 result-awareness property: with SBR mitigation the
    // CA:AZ ratio at the sink converges toward the true 6.85 while the
    // run is still in progress.
    let workers = 8;
    let (w, handle, join) = w1(150_000, workers);
    let plugin = ReshapePlugin::new(join, Approach::SplitByRecords, true);
    let exec = Execution::start_with_plugin(w, reshape_cfg(), Box::new(plugin));
    // Sample the observed ratio while running.
    let mut best_mid_run = f64::NAN;
    for _ in 0..400 {
        std::thread::sleep(Duration::from_millis(5));
        let r = handle.ratio(tweets::CA, tweets::AZ);
        if r.is_finite() {
            best_mid_run = r;
        }
        if handle.total() > 120_000 {
            break;
        }
    }
    exec.join();
    let final_ratio = handle.ratio(tweets::CA, tweets::AZ);
    assert!(
        (final_ratio - tweets::CA_AZ_RATIO).abs() / tweets::CA_AZ_RATIO < 0.15,
        "final ratio {final_ratio} far from {}",
        tweets::CA_AZ_RATIO
    );
    // Mid-run the mitigated ratio should already be well above the
    // unmitigated ~1.0 plateau.
    assert!(
        best_mid_run > 2.0,
        "mid-run ratio {best_mid_run} stuck near the unmitigated plateau"
    );
}

#[test]
fn flux_cannot_split_heavy_hitter() {
    // Flux moves whole keys only; the CA worker keeps its heavy hitter
    // so its processed count stays dominant (Fig. 3.20's ~0.06 ratio).
    let workers = 8;
    let (w, _handle, join) = w1(80_000, workers);
    let plugin = FluxPlugin::new(join);
    // Flux observes an initial window before acting ("Flux used a 2
    // second initial duration to detect overloaded keys", §3.7.1;
    // scaled down) so its key-distribution sample is representative.
    let cfg = Config { reshape_initial_delay_ms: 100, ..reshape_cfg() };
    let exec = Execution::start_with_plugin(w, cfg, Box::new(plugin));
    let summary = exec.join();
    let ca_worker = worker_of(tweets::CA, workers);
    let ca_processed = summary
        .worker_stats
        .iter()
        .find(|(id, _)| id.op == join && id.idx == ca_worker)
        .map(|(_, s)| s.processed)
        .unwrap();
    // Expected CA tweet volume from the generator's weights.
    let weights = tweets::state_weights();
    let ca_share = weights[tweets::CA] / weights.iter().sum::<f64>();
    let expected_ca = (80_000.0 * ca_share) as u64;
    // Flux cannot split a single key: the CA worker still processed at
    // least (almost) all CA tweets itself.
    assert!(
        ca_processed as f64 >= expected_ca as f64 * 0.9,
        "CA hot key appears split by Flux: processed {ca_processed}, CA volume ≈ {expected_ca}"
    );
    assert_eq!(summary.produced(join), 80_000);
}

#[test]
fn sort_sbr_scattered_state_merges_correctly() {
    // Range-partitioned sort under SBR mitigation: foreign runs are
    // shipped back at EOF (§3.5.4) and the merged output is globally
    // ordered with no loss.
    let n = 30_000usize;
    let bounds = vec![Value::Int(6_000), Value::Int(24_000)]; // skewed middle range
    let b2 = bounds.clone();
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let data: Vec<Tuple> = (0..n)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(data)) as Box<dyn TupleSource>
    }));
    let sort = w.add(
        OpSpec::unary(
            "sort",
            3,
            PartitionScheme::Range { key: 0, bounds: bounds.clone() },
            // Per-tuple cost keeps the sort workers the bottleneck so
            // the skewed middle range reliably builds a queue.
            move |idx, _| {
                Box::new(SortWorker::new(0, idx as u64, b2.clone()).with_cost(3_000))
            },
        )
        .with_blocking(vec![0])
        .with_scatter_merge(),
    );
    let merge = w.add(
        OpSpec::unary("merge", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(0))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, sort, 0);
    w.connect(sort, merge, 0);
    w.connect(merge, sink, 0);

    // Mutable-state operator: no upfront state replication.
    let plugin = ReshapePlugin::new(sort, Approach::SplitByRecords, false);
    let report = plugin.report();
    let cfg = Config {
        batch_size: 32,
        data_queue_cap: 8,
        reshape_eta: 50.0,
        reshape_tau: 50.0,
        ..Config::default()
    };
    let exec = Execution::start_with_plugin(w, cfg, Box::new(plugin));
    exec.join();
    let rows = handle.tuples();
    assert_eq!(rows.len(), n, "scattered-state merge lost/duplicated tuples");
    let vals: Vec<i64> = rows.iter().map(|t| t.get(0).as_int().unwrap()).collect();
    let mut sorted = vals.clone();
    sorted.sort_unstable();
    assert_eq!(vals, sorted, "global order violated after SBR on sort");
    // Some sort worker was mitigated (which one wins the detection
    // race depends on timing; exactness is asserted above either way).
    let rep = report.lock().unwrap();
    assert!(
        !rep.mitigations.is_empty(),
        "no sort worker was ever mitigated"
    );
}

#[test]
fn sbk_groupby_marker_synchronized_migration() {
    // Mutable-state SBK (§3.5.3): a CA-skewed group-by count; Reshape
    // moves whole keys to the helper, with the running aggregates
    // migrating at the marker-aligned safe point. Counts must be exact.
    use texera_amber::operators::{AggKind, GroupByFinal};
    use texera_amber::operators::basic::MapUdf;

    let total = 60_000usize;
    let workers = 6usize;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("tweet_scan", 2, move |idx, parts| {
        Box::new(TweetSource::new(total, parts, idx, 0x5EED)) as Box<dyn TupleSource>
    }));
    // Slow per-tuple stage inside the group-by workers' feed keeps the
    // group-by the bottleneck: model with a costly pre-projection that
    // emits (location, 1).
    let prep = w.add(OpSpec::unary("prep", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(MapUdf {
            f: Box::new(|t: &Tuple| {
                Tuple::new(vec![t.get(tweets::F_LOCATION).clone(), Value::Float(1.0)])
            }),
            cost_ns: 0,
        })
    }));
    // Single-layer hash group-by (GroupByFinal sums partials — feeding
    // it (key, 1.0) rows makes it a plain count), with a per-tuple cost
    // via a wrapper: use the engine-level queue bottleneck instead by
    // tiny queues.
    let gb = w.add(
        OpSpec::unary("group_by", workers, PartitionScheme::Hash { key: 0 }, |idx, n| {
            Box::new(GroupByFinal::new_partitioned(AggKind::Sum, idx, n))
        })
        .with_blocking(vec![0])
        .with_scatter_merge(),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, prep, 0);
    w.connect(prep, gb, 0);
    w.connect(gb, sink, 0);

    // SBK on a mutable-state operator: keys move, aggregates migrate at
    // marker alignment (replicate=false).
    let plugin = ReshapePlugin::new(gb, Approach::SplitByKeys, false);
    let report = plugin.report();
    let cfg = Config {
        batch_size: 32,
        data_queue_cap: 8,
        reshape_eta: 60.0,
        reshape_tau: 60.0,
        ..Config::default()
    };
    let exec = Execution::start_with_plugin(w, cfg, Box::new(plugin));
    exec.join();

    // Exactness: per-location counts must match the generator exactly —
    // any key double-counted (state replicated instead of moved) or
    // lost (moved before alignment) breaks this.
    let mut expected = vec![0f64; tweets::NUM_STATES];
    let mut src = TweetSource::new(total, 1, 0, 0x5EED);
    while let Some(t) = src.next_tuple() {
        expected[t.get(tweets::F_LOCATION).as_int().unwrap() as usize] += 1.0;
    }
    let rows = handle.tuples();
    let mut got = vec![0f64; tweets::NUM_STATES];
    for r in &rows {
        got[r.get(0).as_int().unwrap() as usize] = r.get(1).as_float().unwrap();
    }
    assert_eq!(got, expected, "SBK migration corrupted group counts");
    // A mitigation actually happened (otherwise this test proves nothing).
    let rep = report.lock().unwrap();
    assert!(
        !rep.mitigations.is_empty(),
        "no skew detected — test setup lost its bottleneck"
    );
}

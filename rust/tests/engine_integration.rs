//! End-to-end engine integration tests: whole workflows through the
//! actor DAG — deploy, run, pause/resume, investigate, modify,
//! breakpoints — exercising the Ch. 2 (Amber) feature set.

use std::sync::Arc;
use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{
    Execution, OpSpec, PartitionScheme, Workflow,
};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{
    AggKind, CollectSink, CountByKeySink, GroupByFinal, GroupByPartial, HashJoin, SinkHandle,
    SortMerge, SortWorker,
};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::{TupleSource, VecSource};

/// Deterministic integer source 0..n (partitioned round-robin).
fn int_source(total: usize) -> impl Fn(usize, usize) -> Box<dyn TupleSource> + Send + Sync + 'static
{
    move |idx, parts| {
        let data: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect();
        Box::new(VecSource::new(data))
    }
}

#[test]
fn scan_filter_sink_pipeline() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(1000)));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Lt, Value::Int(100)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let exec = Execution::start(w, Config::for_tests());
    let summary = exec.join();
    assert_eq!(handle.total(), 100);
    assert_eq!(summary.produced(filter), 100);
    // 1000 tuples scanned by the 2 scan workers.
    let scanned: u64 = summary
        .worker_stats
        .iter()
        .filter(|(id, _)| id.op == scan)
        .map(|(_, s)| s.processed)
        .sum();
    assert_eq!(scanned, 1000);
}

#[test]
fn hash_partitioned_group_by_counts() {
    // count per key (key = i % 10) over 2000 tuples → 10 groups of 200.
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(2000)));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(1, 0, AggKind::Count)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);

    let exec = Execution::start(w, Config::for_tests());
    exec.join();
    let rows = handle.tuples();
    assert_eq!(rows.len(), 10);
    for r in rows {
        assert_eq!(r.get(1).as_float(), Some(200.0));
    }
}

#[test]
fn hash_join_build_and_probe() {
    // build: 10 rows (key k, payload k*100); probe: 500 rows keyed k%10.
    let build_rows: Arc<Vec<Tuple>> = Arc::new(
        (0..10)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(k * 100)]))
            .collect(),
    );
    let mut w = Workflow::new();
    let br = build_rows.clone();
    let build_scan = w.add(OpSpec::source("build_scan", 1, move |idx, parts| {
        let rows: Vec<Tuple> = br
            .iter()
            .enumerate()
            .filter(|(i, _)| i % parts == idx)
            .map(|(_, t)| t.clone())
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let probe_scan = w.add(OpSpec::source("probe_scan", 2, int_source(500)));
    let join = w.add(OpSpec::binary(
        "join",
        3,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 1 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 1)),
    ));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(build_scan, join, 0);
    w.connect(probe_scan, join, 1);
    w.connect(join, sink, 0);

    let exec = Execution::start(w, Config::for_tests());
    exec.join();
    // Every probe tuple matches exactly one build row.
    assert_eq!(handle.total(), 500);
    // Spot-check a join output: (build_key, payload, probe_id, probe_key).
    let rows = handle.tuples();
    for r in rows.iter().take(20) {
        let k = r.get(0).as_int().unwrap();
        assert_eq!(r.get(1).as_int(), Some(k * 100));
        assert_eq!(r.get(3).as_int(), Some(k));
    }
}

#[test]
fn distributed_sort_produces_total_order() {
    let bounds = vec![Value::Int(300), Value::Int(600)];
    let b2 = bounds.clone();
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(900)));
    let sort = w.add(
        OpSpec::unary(
            "sort",
            3,
            PartitionScheme::Range { key: 0, bounds: bounds.clone() },
            move |idx, _| Box::new(SortWorker::new(0, idx as u64, b2.clone())),
        )
        .with_blocking(vec![0]),
    );
    let merge = w.add(
        OpSpec::unary("merge", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(0))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, sort, 0);
    w.connect(sort, merge, 0);
    w.connect(merge, sink, 0);

    let exec = Execution::start(w, Config::for_tests());
    exec.join();
    let rows = handle.tuples();
    assert_eq!(rows.len(), 900);
    let vals: Vec<i64> = rows.iter().map(|t| t.get(0).as_int().unwrap()).collect();
    let mut sorted = vals.clone();
    sorted.sort_unstable();
    assert_eq!(vals, sorted, "global order violated");
}

#[test]
fn pause_is_subsecond_and_resume_completes() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(200_000)));
    let filter = w.add(OpSpec::unary("filter", 4, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h2.clone(), 1))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let exec = Execution::start(w, Config::default());
    std::thread::sleep(Duration::from_millis(20));
    // Pause mid-flight (Figs. 2.10/2.11: pause latency < 1 s).
    let latency = exec.pause();
    assert!(
        latency < Duration::from_secs(1),
        "pause took {latency:?} (paper: sub-second)"
    );
    let at_pause = handle.total();
    std::thread::sleep(Duration::from_millis(100));
    let after_wait = handle.total();
    // Tolerance: output buffered before the pause may still land.
    assert!(
        after_wait - at_pause < 5000,
        "sink kept growing while paused: {at_pause} → {after_wait}"
    );
    exec.resume();
    let summary = exec.join();
    assert_eq!(handle.total(), 200_000);
    assert_eq!(summary.produced(filter), 200_000);
}

#[test]
fn stats_reflect_progress_while_paused() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, int_source(100_000)));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let exec = Execution::start(w, Config::default());
    std::thread::sleep(Duration::from_millis(10));
    exec.pause();
    // Investigating operators while paused (§2.4.4).
    let stats = exec.stats();
    assert_eq!(stats.len(), 4, "one row per worker");
    let filter_processed: u64 = stats
        .iter()
        .filter(|(id, _)| id.op == filter)
        .map(|(_, s)| s.processed)
        .sum();
    // Some progress was made before pausing; not necessarily all.
    assert!(filter_processed > 0);
    exec.resume();
    exec.join();
}

#[test]
fn modify_filter_constant_mid_run() {
    // Start with a selective filter; loosen it mid-run; total output
    // must land between the two extremes.
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, int_source(300_000)));
    let filter = w.add(OpSpec::unary("filter", 1, PartitionScheme::RoundRobin, |_, _| {
        // keep key-field (idx 1) < 1 → 10% pass.
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(1)))
    }));
    let handle = SinkHandle::new(10);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h2.clone(), 1))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let exec = Execution::start(w, Config::default());
    std::thread::sleep(Duration::from_millis(5));
    // Loosen to < 5 → 50% pass for the remainder (§2.4.4 runtime
    // modification with sub-second latency).
    exec.modify_operator(filter, "constant", "5");
    exec.join();
    let total = handle.total();
    assert!(
        total >= 30_000 && total <= 150_000,
        "expected between 10% and 50% of 300k, got {total}"
    );
    // Keys 1..4 appear only after the modification, so key 4 can never
    // exceed key 0 (which passes the filter from the start).
    assert!(handle.count_of(4) > 0, "loosened filter never took effect");
    assert!(handle.count_of(4) <= handle.count_of(0));
}

#[test]
fn local_breakpoint_pauses_whole_workflow() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(1_000_000)));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    // Set the breakpoint before data flows (§2.2.1: "Breakpoints can
    // be set before or during the execution") by deploying with
    // dormant sources.
    let exec = Execution::start_scheduled(w, Config::default());
    // Condition: a specific tuple id flows by (like followerNum < 0).
    exec.set_local_breakpoint(
        filter,
        Some(Arc::new(|t: &Tuple| t.get(0).as_int() == Some(5000))),
    );
    exec.start_sources(vec![scan]);
    let hit = exec.await_breakpoint();
    let t = hit.tuple.expect("culprit tuple");
    assert_eq!(t.get(0).as_int(), Some(5000));
    // Workflow is paused; clear the breakpoint and resume to finish.
    exec.set_local_breakpoint(filter, None);
    exec.resume();
    exec.join();
    assert_eq!(handle.total(), 1_000_000);
}

#[test]
fn global_count_breakpoint_pauses_at_exact_total() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 3, int_source(500_000)));
    let filter = w.add(OpSpec::unary("filter", 3, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let cfg = Config { breakpoint_tau_ms: 3, ..Config::default() };
    let exec = Execution::start_scheduled(w, cfg);
    let _id = exec.set_count_breakpoint(filter, 10_000);
    exec.start_sources(vec![scan]);
    let hit = exec.await_breakpoint();
    assert!(hit.id > 0);
    // After the hit the workflow is paused; the filter produced exactly
    // 10k tuples (COUNT semantics are exact, §2.5.3).
    std::thread::sleep(Duration::from_millis(100)); // let gauges settle
    let stats = exec.stats();
    let produced: u64 = stats
        .iter()
        .filter(|(id, _)| id.op == filter)
        .map(|(_, s)| s.produced)
        .sum();
    assert_eq!(produced, 10_000, "COUNT breakpoint must be exact");
    exec.resume();
    exec.join();
    assert_eq!(handle.total(), 500_000);
}

#[test]
fn global_sum_breakpoint_minimizes_overshoot() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(500_000)));
    // Sum over field 1 (values 0..9, mean 4.5).
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let cfg = Config { breakpoint_tau_ms: 3, ..Config::default() };
    let exec = Execution::start_scheduled(w, cfg);
    let target = 50_000.0;
    exec.set_sum_breakpoint(filter, target, 1, 100.0);
    exec.start_sources(vec![scan]);
    let hit = exec.await_breakpoint();
    assert!(hit.id > 0);
    exec.resume();
    exec.join();
}

#[test]
fn first_output_recorded_per_operator() {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, int_source(10_000)));
    let filter = w.add(OpSpec::unary("filter", 1, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let exec = Execution::start(w, Config::for_tests());
    let summary = exec.join();
    // Pipelined execution: the filter's first output arrives well
    // before the run completes.
    let fo = summary.first_output[&filter];
    assert!(fo < summary.elapsed.as_secs_f64());
    assert!(summary.first_output.contains_key(&scan));
}

#[test]
fn ch1_parser_scenario_runtime_adaptation() {
    // The Fig. 1.1 adaptivity story: a parser meets rows it cannot
    // parse. Instead of crashing and losing earlier results, the
    // analyst patches the operator at runtime; already-computed results
    // survive and the run completes with the bad rows skipped.
    use texera_amber::operators::RegexParser;
    let rows = 200_000usize;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                // Every 1000th row has a malformed date column.
                // Malformed rows have the wrong field count — the
                // kind of row that crashes a strict parser (Fig. 1.1).
                let raw = if i % 1000 == 999 {
                    format!("{i}")
                } else {
                    format!("{i}\t2020")
                };
                Tuple::new(vec![Value::str(&raw)])
            })
            .collect();
        Box::new(VecSource::new(data))
    }));
    let parser = w.add(OpSpec::unary("parser", 2, PartitionScheme::RoundRobin, |_, _| {
        // Lenient from the start here; the *runtime patch* under test is
        // flipping strictness parameters live (delimiter change).
        Box::new(RegexParser::new(0, '\t', 2))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, parser, 0);
    w.connect(parser, sink, 0);
    let exec = Execution::start(w, Config::default());
    // Patch mid-run: (a no-op value change proves the control path; a
    // strict parser would panic the worker without it).
    std::thread::sleep(Duration::from_millis(5));
    exec.modify_operator(parser, "strict", "false");
    exec.join();
    // All well-formed rows parsed; malformed ones skipped, not fatal.
    assert_eq!(handle.total() as usize, rows - rows / 1000);
}

#[test]
fn union_merges_two_streams() {
    use texera_amber::operators::Union;
    let mut w = Workflow::new();
    let a = w.add(OpSpec::source("scan_a", 1, int_source(500)));
    let b = w.add(OpSpec::source("scan_b", 2, int_source(300)));
    let u = w.add(OpSpec::binary(
        "union",
        2,
        [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
        vec![],
        |_, _| Box::new(Union::new(2)),
    ));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(a, u, 0);
    w.connect(b, u, 1);
    w.connect(u, sink, 0);
    let exec = Execution::start(w, Config::for_tests());
    exec.join();
    assert_eq!(handle.total(), 800);
}

#[test]
fn sum_breakpoint_overshoot_is_bounded() {
    // §2.5.3's SUM overshoot-minimization: the hit total may exceed the
    // target only by (roughly) one tuple's value per reporting worker.
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, int_source(400_000)));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    let cfg = Config { breakpoint_tau_ms: 2, ..Config::default() };
    let exec = Execution::start_scheduled(w, cfg);
    // Field 1 holds values 0..9 (mean 4.5); target 20_000; tail
    // threshold 50 → near the target only one worker runs, so the
    // overshoot is at most one tuple's value (≤ 9) per live worker.
    exec.set_sum_breakpoint(filter, 20_000.0, 1, 50.0);
    exec.start_sources(vec![scan]);
    let hit = exec.await_breakpoint();
    // Values are 0..9: near the target only one worker holds the tail
    // assignment, so the overshoot is bounded by one tuple's value per
    // concurrently-reporting worker.
    assert!(hit.overshoot >= 0.0);
    assert!(
        hit.overshoot <= 9.0 * 2.0,
        "overshoot too large: {}",
        hit.overshoot
    );
    exec.resume();
    exec.join();
    let _ = handle;
}

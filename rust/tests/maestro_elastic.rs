//! Elastic region scheduling end-to-end: an observation-driven
//! schedule (worker budget set, counts re-planned between region
//! activations via fenced scales on dormant operators) must produce
//! byte-identical sink multisets to the static schedule, and a
//! deliberately wrong initial cost model must lead the post-region
//! re-plan to a different worker assignment than the initial plan.

use texera_amber::config::Config;
use texera_amber::engine::{OpSpec, PartitionScheme, Workflow};
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::MaestroScheduler;
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{CollectSink, HashJoin, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// Cyclic-region workflow (the Fig. 4.1 pathology with real operators):
/// one scan replicates into the build filter and, through a pass-all
/// prep filter, into the probe of a strict join — so Maestro must
/// materialize a probe-path edge and schedule two regions, the second
/// gated on a dormant mat reader.
///
/// Keys: rows `i < 64` carry key `i` (so the build side, `val < 64`,
/// holds exactly one row per key); later rows are uniform (`i % 64`) or
/// 90%-hot-key-0 skewed. Every probe row therefore joins exactly one
/// build row and the join output multiset has `rows` tuples.
fn cyclic_workflow(rows: usize, skewed: bool) -> (Workflow, SinkHandle, usize, usize) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                let key = if i < 64 {
                    i as i64
                } else if skewed {
                    if i % 10 != 0 { 0 } else { (i % 64) as i64 }
                } else {
                    (i % 64) as i64
                };
                Tuple::new(vec![Value::Int(key), Value::Int(i as i64)])
            })
            .collect();
        Box::new(VecSource::new(data))
    }));
    let prep = w.add(OpSpec::unary("prep", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
    }));
    let buildf = w.add(OpSpec::unary("buildf", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(64)))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, prep, 0);
    w.connect(scan, buildf, 0);
    w.connect(buildf, join, 0);
    w.connect(prep, join, 1);
    w.connect(join, sink, 0);
    (w, handle, sink, join)
}

/// Canonical multiset of sink tuples: sorted debug renderings (the
/// byte-identical comparison the chaos/equivalence suites use).
fn multiset(handle: &SinkHandle) -> Vec<String> {
    let mut rows: Vec<String> = handle.tuples().iter().map(|t| format!("{t:?}")).collect();
    rows.sort_unstable();
    rows
}

fn run_mode(rows: usize, skewed: bool, budget: usize) -> (Vec<String>, u64) {
    let (w, handle, sink, _) = cyclic_workflow(rows, skewed);
    let mut cost = CostParams::new();
    cost.source_rows.insert(0, rows as f64);
    cost.selectivity.insert(2, 64.0 / rows as f64); // buildf tiny
    let cfg = Config {
        batch_size: 1024,
        ctrl_check_interval: 1024,
        max_workers: budget,
        ..Config::for_tests()
    };
    let sched = MaestroScheduler::new(cfg, cost);
    let outcome = sched.run(w, &[sink]);
    assert!(outcome.measured_frt.is_finite());
    if budget > 0 {
        assert!(
            !outcome.replans.is_empty(),
            "elastic schedule never re-planned"
        );
    }
    (multiset(&handle), handle.total())
}

#[test]
fn elastic_schedule_matches_static_uniform_batch_1024() {
    let rows = 4000;
    let (static_rows, static_total) = run_mode(rows, false, 0);
    let (elastic_rows, elastic_total) = run_mode(rows, false, 6);
    assert_eq!(static_total, rows as u64);
    assert_eq!(elastic_total, static_total);
    assert_eq!(
        elastic_rows, static_rows,
        "elastic schedule changed the sink multiset (uniform)"
    );
}

#[test]
fn elastic_schedule_matches_static_skewed_batch_1024() {
    let rows = 4000;
    let (static_rows, static_total) = run_mode(rows, true, 0);
    let (elastic_rows, elastic_total) = run_mode(rows, true, 6);
    assert_eq!(static_total, rows as u64);
    assert_eq!(elastic_total, static_total);
    assert_eq!(
        elastic_rows, static_rows,
        "elastic schedule changed the sink multiset (90% hot key)"
    );
}

#[test]
fn wrong_initial_costs_lead_replan_to_different_assignment() {
    let rows = 4000;
    let (w, handle, sink, join) = cyclic_workflow(rows, false);
    // Deliberately wrong initial model: the scan is claimed to produce
    // 4 rows (actual: 4000), so the initial per-region assignment is
    // starved by the rows cap; the join is expensive, so once the
    // observed cardinalities land, the re-plan shifts budget onto it.
    let mut cost = CostParams::new();
    cost.source_rows.insert(0, 4.0);
    cost.tuple_cost.insert(join, 50.0);
    let cfg = Config {
        batch_size: 1024,
        ctrl_check_interval: 1024,
        max_workers: 12,
        ..Config::for_tests()
    };
    let sched = MaestroScheduler::new(cfg, cost);
    let outcome = sched.run(w, &[sink]);
    // Results stay correct across the dormant-operator scale fences.
    assert_eq!(handle.total(), rows as u64, "elastic re-plan lost tuples");
    // The trail recorded large estimation errors…
    let worst_q = outcome
        .replans
        .iter()
        .flat_map(|r| r.observed.iter())
        .map(|o| o.q_error)
        .fold(0.0f64, f64::max);
    assert!(
        worst_q >= 10.0,
        "expected a large q-error from the wrong model, got {worst_q}"
    );
    // …and the re-plan moved to a different assignment than the initial
    // plan, applying at least one fenced scale on a dormant operator.
    assert_ne!(
        outcome.initial_workers, outcome.final_workers,
        "re-plan never changed the worker assignment: {outcome:?}"
    );
    let applied: Vec<_> = outcome
        .replans
        .iter()
        .flat_map(|r| r.decisions.iter())
        .filter(|d| d.applied)
        .collect();
    assert!(
        !applied.is_empty(),
        "no scale decision was applied: {:?}",
        outcome.replans
    );
    assert!(applied.iter().all(|d| d.fence_ms > 0.0));
    // The starved join specifically gained workers.
    assert!(
        outcome.final_workers[join] > outcome.initial_workers[join],
        "join not scaled up: initial {:?} final {:?}",
        outcome.initial_workers,
        outcome.final_workers
    );
}

//! Supervised execution (robustness): panic containment, heartbeat
//! stall detection, automatic replay-based recovery, and deterministic
//! fault injection — injected failures at arbitrary replay positions
//! must be detected, recovered, and leave sink results byte-exact
//! versus an un-faulted run of the same workflow; retry exhaustion must
//! terminate with a structured error, never a hang.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{
    ExecError, ExecSummary, Execution, Fault, FaultPlan, OpSpec, PartitionScheme, WorkerId,
    Workflow,
};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{AggKind, CollectSink, GroupByFinal, GroupByPartial, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// scan → filter → group-by(count per key) → sink; deterministic input.
/// Operator indices: scan=0, filter=1, gb_partial=2, gb_final=3, sink=4.
fn wf(total: usize, handle: SinkHandle) -> Workflow {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(8))) // keep 80%
    }));
    let partial = w.add(OpSpec::unary("gb_partial", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(GroupByPartial::new(1, 0, AggKind::Count))
    }));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Count))
        })
        .with_blocking(vec![0]),
    );
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    w
}

/// scan → filter → sink (no aggregation): the sink sees tens of
/// thousands of tuples, so positional faults deep into its stream are
/// reachable. Operator indices: scan=0, filter=1, sink=2.
fn wf_passthrough(total: usize, handle: SinkHandle) -> Workflow {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Lt, Value::Int(8)))
    }));
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);
    w
}

fn expected_counts(total: usize) -> Vec<(i64, f64)> {
    // keys 0..7 kept; each appears total/10 times.
    (0..8).map(|k| (k, (total / 10) as f64)).collect()
}

fn result_counts(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut rows: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    rows.sort_by_key(|(k, _)| *k);
    rows
}

/// Sorted multiset of (id, key) rows captured by a pass-through sink.
fn result_rows(handle: &SinkHandle) -> Vec<(i64, i64)> {
    let mut rows: Vec<(i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_int().unwrap()))
        .collect();
    rows.sort_unstable();
    rows
}

fn expected_rows(total: usize) -> Vec<(i64, i64)> {
    (0..total as i64).filter(|i| i % 10 < 8).map(|i| (i, i % 10)).collect()
}

/// Supervised config: recovery on, fast heartbeat + checkpoint cadence,
/// short backoff so tests run quickly.
fn supervised(plan: FaultPlan) -> Config {
    Config {
        ft_log: true,
        heartbeat_timeout_ms: 150,
        checkpoint_interval_ms: 20,
        recovery_backoff_ms: 5,
        fault_plan: plan,
        ..Config::default()
    }
}

/// Join with a hard wall-clock bound — the structured-abort promise is
/// "never a hang", so every supervised test terminates through here.
fn join_within(exec: Execution, timeout: Duration) -> ExecSummary {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let summary = exec.join();
        drop(exec);
        let _ = tx.send(summary);
    });
    rx.recv_timeout(timeout)
        .expect("supervised execution did not terminate within the deadline")
}

fn plan(faults: Vec<Fault>) -> FaultPlan {
    let mut p = FaultPlan::default();
    for f in faults {
        p.push(f);
    }
    p
}

#[test]
fn panic_in_source_worker_recovers_exact() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    let cfg = supervised(plan(vec![Fault::panic_at(WorkerId::new(0, 1), 1024)]));
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None, "supervised run ended in error");
    assert!(summary.supervision.crashes_detected >= 1, "panic was not detected");
    assert!(summary.supervision.recoveries >= 1, "no recovery cycle ran");
    assert_eq!(result_counts(&handle), expected_counts(total));
}

#[test]
fn panic_in_stateful_groupby_recovers_exact() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    let cfg = supervised(plan(vec![Fault::panic_at(WorkerId::new(2, 0), 256)]));
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None, "supervised run ended in error");
    assert!(summary.supervision.crashes_detected >= 1);
    assert!(summary.supervision.recoveries >= 1);
    assert_eq!(result_counts(&handle), expected_counts(total));
}

#[test]
fn panic_in_sink_worker_recovers_exact() {
    let total = 50_000;
    let handle = SinkHandle::new(0);
    let cfg = supervised(plan(vec![Fault::panic_at(WorkerId::new(2, 0), 1024)]));
    let exec = Execution::start(wf_passthrough(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None, "supervised run ended in error");
    assert!(summary.supervision.crashes_detected >= 1);
    assert!(summary.supervision.recoveries >= 1);
    // Byte-exact multiset: recovery must not lose rows *or* leave the
    // pre-crash sink captures double-counted.
    assert_eq!(result_rows(&handle), expected_rows(total));
}

#[test]
fn stall_is_detected_by_heartbeat_and_recovered() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    // The filter worker goes heartbeat-silent for 600 ms — well past
    // the 150 ms timeout — without panicking.
    let cfg = supervised(plan(vec![Fault::stall_at(WorkerId::new(1, 0), 512, 600)]));
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None, "supervised run ended in error");
    assert!(
        summary.supervision.stalls_detected >= 1,
        "stall was not detected via heartbeat silence: {:?}",
        summary.supervision
    );
    assert!(summary.supervision.recoveries >= 1);
    assert_eq!(result_counts(&handle), expected_counts(total));
}

#[test]
fn retry_exhaustion_aborts_with_structured_error() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    // The fault re-fires on every respawn (shared counter, 10 allowed
    // firings > 2 allowed retries), so recovery can never make
    // progress past it and must escalate to a clean abort.
    let p = plan(vec![Fault::panic_at(WorkerId::new(0, 0), 32).times(10)]);
    let cfg = Config { recovery_max_retries: 2, ..supervised(p) };
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    match summary.error {
        Some(ExecError::RecoveryExhausted { attempts, .. }) => assert_eq!(attempts, 2),
        other => panic!("expected RecoveryExhausted, got {other:?}"),
    }
    assert!(summary.supervision.retries_exhausted);
    assert_eq!(summary.supervision.recoveries, 2);
}

#[test]
fn unsupervised_failure_aborts_cleanly() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    // ft_log off: no replay log, so recovery is unavailable — the run
    // must still terminate with a structured error, not hang.
    let cfg = Config {
        ft_log: false,
        fault_plan: plan(vec![Fault::panic_at(WorkerId::new(1, 1), 256)]),
        ..Config::default()
    };
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    match summary.error {
        Some(ExecError::Unsupervised { worker, .. }) => {
            assert_eq!(worker, WorkerId::new(1, 1));
        }
        other => panic!("expected Unsupervised abort, got {other:?}"),
    }
}

#[test]
fn crash_during_scale_fence_rolls_back_then_recovers() {
    let total = 400_000;
    let handle = SinkHandle::new(0);
    let cfg = supervised(plan(vec![Fault::panic_at(WorkerId::new(2, 0), 2048)]));
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    // Race a scale fence against the injected crash. Whichever wins,
    // the fence either completes before the failure or aborts and
    // rolls back when the failure lands mid-fence; recovery then
    // redeploys at whatever plan survived. Results must stay exact.
    std::thread::sleep(Duration::from_millis(5));
    let _ = exec.scale_operator(1, 3);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None, "supervised run ended in error");
    assert!(summary.supervision.crashes_detected >= 1);
    assert_eq!(result_counts(&handle), expected_counts(total));
}

#[test]
fn delay_fault_preserves_exactness_without_recovery() {
    let total = 100_000;
    let handle = SinkHandle::new(0);
    // A delayed batch perturbs timing but not order (the sender
    // blocks, per-edge FIFO holds): no failure is declared and the
    // results are identical to an un-faulted run.
    let cfg = supervised(plan(vec![Fault::delay_nth(WorkerId::new(0, 0), 1, 3, 50)]));
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None);
    assert_eq!(summary.supervision.failures_detected(), 0);
    assert_eq!(result_counts(&handle), expected_counts(total));
}

#[test]
fn automatic_checkpoints_run_on_the_configured_cadence() {
    let total = 600_000;
    let handle = SinkHandle::new(0);
    let cfg = Config {
        ft_log: true,
        checkpoint_interval_ms: 10,
        ..Config::default()
    };
    let exec = Execution::start(wf(total, handle.clone()), cfg);
    let summary = join_within(exec, Duration::from_secs(60));
    assert_eq!(summary.error, None);
    assert!(
        summary.supervision.auto_checkpoints >= 1,
        "no automatic checkpoint completed: {:?}",
        summary.supervision
    );
    assert_eq!(result_counts(&handle), expected_counts(total));
}

//! Tenant-isolation suite for the multi-tenant serving layer.
//!
//! The contract under test: N workflows racing through one
//! [`EngineService`] produce results **byte-identical** to running each
//! alone; the global worker budget is never exceeded; and one tenant's
//! misbehavior — a panicking workflow, an exhausted quota — cannot
//! stall or corrupt anyone else's results.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{
    Emitter, Execution, Fault, FaultPlan, OpSpec, Operator, PartitionScheme, WorkerId,
    Workflow,
};
use texera_amber::operators::{
    AggKind, CollectSink, GroupByFinal, GroupByPartial, SinkHandle,
};
use texera_amber::service::{
    AdmissionError, EngineService, JobId, ServiceConfig, Submission, TenantId, TenantQuota,
};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// scan → group-by partial → group-by final (blocking) → collect sink.
/// `hot` sends 90% of rows to one key (the skewed-shuffle shape);
/// otherwise keys are uniform over 0..50.
fn counting_flow(n: usize, hot: bool, workers: usize) -> (Workflow, SinkHandle) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", workers, move |idx, parts| {
        let rows: Vec<Tuple> = (0..n)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                let key = if hot && i % 10 != 0 { 7 } else { (i % 50) as i64 };
                Tuple::new(vec![Value::Int(key), Value::Int(i as i64)])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        workers,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(0, 1, AggKind::Sum)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", workers, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);
    (w, handle)
}

fn sorted_rows(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut rows: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    rows.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rows
}

fn result_rows(rows: &[Tuple]) -> Vec<(i64, f64)> {
    let mut out: Vec<(i64, f64)> = rows
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

/// A filter-shaped operator that sleeps per tuple — makes a job run
/// long enough to be observably *concurrent* without any timing
/// assumption beyond "milliseconds add up".
struct SlowPass {
    per_tuple: Duration,
}

impl Operator for SlowPass {
    fn name(&self) -> &str {
        "slow_pass"
    }

    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        std::thread::sleep(self.per_tuple);
        out.emit(t);
    }
}

/// scan → slow pass → collect sink, `n` tuples × `per_tuple_us` each.
fn slow_flow(n: usize, per_tuple_us: u64) -> (Workflow, SinkHandle) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..n)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let slow = w.add(OpSpec::unary("slow", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(SlowPass { per_tuple: Duration::from_micros(per_tuple_us) })
    }));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(scan, slow, 0);
    w.connect(slow, sink, 0);
    (w, handle)
}

/// 16 concurrent workflows — uniform and 90%-hot-key, at batch 32 and
/// 1024 — must each match a sequential single-workflow run byte for
/// byte, while four tenants share a 9-worker budget (worker-share
/// quotas force genuine interleaving) and the ledger never overdraws.
#[test]
fn concurrent_workflows_match_sequential_runs() {
    const JOBS: usize = 16;
    const BUDGET: usize = 9;
    for (batch, hot) in [(32usize, false), (32, true), (1024, false), (1024, true)] {
        let job_cfg = Config { batch_size: batch, ..Config::default() };

        // Sequential reference: one engine, one workflow, same config.
        let (w, h) = counting_flow(4000, hot, 2);
        Execution::start(w, job_cfg.clone()).join();
        let expected = sorted_rows(&h);
        assert!(!expected.is_empty(), "reference run produced nothing");

        let mut cfg = ServiceConfig::for_tests();
        cfg.engine.max_workers = BUDGET;
        cfg.default_quota = TenantQuota { max_worker_share: 0.5, ..TenantQuota::default() };
        let svc = EngineService::start(cfg);
        let mut handles: Vec<(JobId, SinkHandle)> = Vec::new();
        for i in 0..JOBS {
            let (w, h) = counting_flow(4000, hot, 2);
            let sub = Submission::new(TenantId((i % 4) as u64), w)
                .with_sink(h.clone())
                .with_config(job_cfg.clone());
            let id = svc.submit(sub).expect("admission");
            handles.push((id, h));
        }
        for (id, h) in handles {
            let r = svc.wait(id).expect("job known");
            assert!(r.error.is_none(), "batch={batch} hot={hot}: {:?}", r.error);
            assert!(!r.cancelled);
            assert_eq!(
                sorted_rows(&h),
                expected,
                "batch={batch} hot={hot} job {id:?} diverged from sequential run"
            );
            assert_eq!(result_rows(&r.rows), expected, "result rows diverge from sink");
            assert!(r.measured_frt.is_some(), "sink emitted, frt must be measured");
        }
        assert!(
            svc.ledger().peak() <= BUDGET,
            "budget exceeded: peak {} > {BUDGET}",
            svc.ledger().peak()
        );
        let s = svc.stats();
        assert_eq!(s.completed, JOBS as u64);
        assert_eq!(s.failed, 0);
    }
}

/// A tenant whose workflow panics (supervision off → clean structured
/// abort, per the PR-8 contract) cannot stall or corrupt the other
/// tenants' jobs, and the service stays serviceable afterwards.
#[test]
fn panicking_tenant_cannot_stall_or_corrupt_others() {
    let (w, h) = counting_flow(4000, false, 2);
    Execution::start(w, Config::for_tests()).join();
    let expected = sorted_rows(&h);

    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 12;
    let svc = EngineService::start(cfg);

    // Victim tenant: inject a deterministic panic in gb_partial worker
    // 0; ft_log is off, so the job must abort cleanly with
    // ExecError::Unsupervised instead of recovering (or hanging).
    let mut faulty_cfg = Config::for_tests();
    faulty_cfg.fault_plan = {
        let mut p = FaultPlan::default();
        p.push(Fault::panic_at(WorkerId::new(1, 0), 5));
        p
    };
    let (fw, fh) = counting_flow(4000, false, 2);
    let faulty = svc
        .submit(
            Submission::new(TenantId(0), fw)
                .with_sink(fh)
                .with_config(faulty_cfg),
        )
        .expect("admission");

    let mut healthy = Vec::new();
    for i in 0..4 {
        let (w, h) = counting_flow(4000, false, 2);
        let id = svc
            .submit(
                Submission::new(TenantId(1 + i as u64), w)
                    .with_sink(h.clone())
                    .with_config(Config::for_tests()),
            )
            .expect("admission");
        healthy.push((id, h));
    }

    let fr = svc.wait(faulty).expect("faulty job known");
    assert!(fr.error.is_some(), "panic must surface as a structured error");
    for (id, h) in healthy {
        let r = svc.wait(id).expect("healthy job known");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(sorted_rows(&h), expected, "neighbor corrupted by tenant-0 panic");
    }
    let s = svc.stats();
    assert_eq!(s.failed, 1);
    assert_eq!(s.completed, 4);

    // Still serviceable after the failure.
    let (w, h) = counting_flow(4000, false, 2);
    let r = svc
        .run(Submission::new(TenantId(9), w).with_sink(h.clone()).with_config(Config::for_tests()))
        .expect("admission");
    assert!(r.error.is_none());
    assert_eq!(sorted_rows(&h), expected);
}

/// A tenant that floods the queue gets `QuotaExceeded` at *its* quota;
/// other tenants keep running. A deferred (admitted) job from the
/// flooding tenant still completes once its earlier job finishes.
#[test]
fn quota_exhausted_tenant_cannot_block_others() {
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 3; // exactly one 3-op job at a time
    cfg.default_quota =
        TenantQuota { max_queued: 1, max_running: 1, ..TenantQuota::default() };
    let svc = EngineService::start(cfg);

    // Tenant 0 occupies the whole budget with a slow job…
    let (w0, h0) = slow_flow(300, 1000);
    let long = svc
        .submit(Submission::new(TenantId(0), w0).with_sink(h0))
        .expect("admission");
    // …queues one more (max_running=1 defers it)…
    let (w1, h1) = slow_flow(10, 10);
    let queued = svc
        .submit(Submission::new(TenantId(0), w1).with_sink(h1))
        .expect("second submission queues");
    // …and the third hits the per-tenant queue quota.
    let (w2, _h2) = slow_flow(10, 10);
    match svc.submit(Submission::new(TenantId(0), w2)) {
        Err(AdmissionError::QuotaExceeded { tenant, max_queued }) => {
            assert_eq!(tenant, TenantId(0));
            assert_eq!(max_queued, 1);
        }
        other => panic!("expected QuotaExceeded, got {other:?}"),
    }

    // Tenant 1 is unaffected by tenant 0's quota exhaustion: admitted,
    // and runs to completion even while tenant 0's long job holds the
    // budget.
    let (w3, h3) = slow_flow(10, 10);
    let neighbor = svc
        .submit(Submission::new(TenantId(1), w3).with_sink(h3.clone()))
        .expect("other tenant admitted");
    let nr = svc.wait(neighbor).expect("neighbor known");
    assert!(nr.error.is_none() && !nr.cancelled);
    assert_eq!(h3.total(), 10);

    let lr = svc.wait(long).expect("long job known");
    assert!(lr.error.is_none());
    let qr = svc.wait(queued).expect("deferred job known");
    assert!(qr.error.is_none() && !qr.cancelled, "admitted job must eventually run");
    assert!(svc.ledger().peak() <= 3, "peak {} > 3", svc.ledger().peak());
}

/// Submitting the same plan twice with the same cache salt serves the
/// second run from the fingerprint cache — same rows, zero workers.
#[test]
fn fingerprint_cache_hit_returns_identical_rows() {
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 8;
    let svc = EngineService::start(cfg);

    let (w, h) = counting_flow(4000, false, 2);
    let cold = svc
        .run(
            Submission::new(TenantId(0), w)
                .with_sink(h.clone())
                .with_config(Config::for_tests())
                .cacheable(0xCAFE),
        )
        .expect("admission");
    assert!(!cold.cache_hit);
    assert!(cold.error.is_none());
    let expected = result_rows(&cold.rows);
    assert_eq!(expected, sorted_rows(&h));

    // Different tenant, same structure + salt → served from cache.
    let (w2, _h2) = counting_flow(4000, false, 2);
    let warm = svc
        .run(Submission::new(TenantId(7), w2).with_config(Config::for_tests()).cacheable(0xCAFE))
        .expect("admission");
    assert!(warm.cache_hit, "second identical plan must hit the cache");
    assert_eq!(warm.workers_granted, 0, "a cache hit deploys no workers");
    assert_eq!(result_rows(&warm.rows), expected, "cached rows diverge from cold run");

    // A different salt (different captured constants) misses.
    let (w3, _h3) = counting_flow(4000, false, 2);
    let other = svc
        .run(
            Submission::new(TenantId(8), w3)
                .with_config(Config::for_tests())
                .cacheable(0xBEEF),
        )
        .expect("admission");
    assert!(!other.cache_hit, "different salt must not collide");
    let s = svc.stats();
    assert_eq!(s.cache_hits, 1);
    assert!(s.cache_misses >= 2);
}

/// An interactive submission arriving while a batch scan holds the
/// whole budget preempts it (pause-fence, grant released), runs, and
/// the batch job then resumes and still produces correct results.
#[test]
fn interactive_preempts_batch_and_batch_recovers() {
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 4;
    let svc = EngineService::start(cfg);

    // Batch scan long enough (~300ms of per-tuple sleeps) to still be
    // running when the interactive job arrives.
    let (bw, bh) = slow_flow(300, 1000);
    let batch = svc
        .submit(Submission::new(TenantId(0), bw).with_sink(bh.clone()))
        .expect("admission");

    let (iw, ih) = counting_flow(2000, false, 2);
    let interactive = svc
        .submit(
            Submission::new(TenantId(1), iw)
                .with_sink(ih.clone())
                .with_config(Config::for_tests())
                .interactive(),
        )
        .expect("admission");

    let ir = svc.wait(interactive).expect("interactive job known");
    assert!(ir.error.is_none() && !ir.cancelled);
    assert!(!sorted_rows(&ih).is_empty());

    let br = svc.wait(batch).expect("batch job known");
    assert!(br.error.is_none() && !br.cancelled);
    assert_eq!(bh.total(), 300, "preempted+resumed batch lost tuples");
    assert!(
        br.preemptions >= 1,
        "batch job should have been pause-preempted for the interactive tenant"
    );
    assert!(svc.ledger().peak() <= 4, "peak {} > 4", svc.ledger().peak());
    assert!(svc.stats().preemptions >= 1);
    assert!(svc.stats().resumes >= 1);
}

/// With an unbounded budget (max_workers = 0) everything runs at
/// authored counts and the ledger just tracks usage.
#[test]
fn unbounded_budget_runs_all_at_authored_counts() {
    let svc = EngineService::start(ServiceConfig::for_tests());
    let mut ids = Vec::new();
    for i in 0..6 {
        let (w, h) = counting_flow(2000, i % 2 == 0, 2);
        let id = svc
            .submit(
                Submission::new(TenantId(i as u64), w)
                    .with_sink(h.clone())
                    .with_config(Config::for_tests()),
            )
            .expect("admission");
        ids.push((id, h));
    }
    for (id, h) in ids {
        let r = svc.wait(id).expect("job known");
        assert!(r.error.is_none() && !r.cancelled);
        assert!(!sorted_rows(&h).is_empty());
        // Authored counts: 2 + 2 + 2 + 1 workers.
        assert_eq!(r.workers_granted, 7);
    }
    assert_eq!(svc.stats().capacity, 0);
    assert!(svc.live_jobs() == 0);
}

/// Cancelling a queued job frees its quota slot; cancelling a running
/// job tears it down and releases its grant for the next job.
#[test]
fn cancellation_releases_budget_and_quota() {
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 3;
    let svc = EngineService::start(cfg);

    let (w0, h0) = slow_flow(300, 1000);
    let running = svc
        .submit(Submission::new(TenantId(0), w0).with_sink(h0))
        .expect("admission");
    let (w1, _h1) = slow_flow(10, 10);
    let queued = svc
        .submit(Submission::new(TenantId(1), w1))
        .expect("admission");

    assert!(svc.cancel(queued), "queued job cancellable");
    let qr = svc.wait(queued).expect("known");
    assert!(qr.cancelled);

    assert!(svc.cancel(running), "running job cancellable");
    let rr = svc.wait(running).expect("known");
    assert!(rr.cancelled);
    assert!(!svc.cancel(running), "double-cancel refused");
    assert_eq!(svc.ledger().used(), 0, "cancelled grants must be released");

    // Budget is genuinely free again.
    let (w2, h2) = slow_flow(10, 10);
    let r = svc
        .run(Submission::new(TenantId(2), w2).with_sink(h2.clone()))
        .expect("admission");
    assert!(r.error.is_none() && !r.cancelled);
    assert_eq!(h2.total(), 10);
}

/// A cacheable submission *without* a result sink runs but must never
/// populate the fingerprint cache — its row set is empty, and storing
/// it would silently serve zero rows to every later identical
/// submission that does carry a sink.
#[test]
fn sinkless_cacheable_submission_does_not_poison_cache() {
    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 8;
    let svc = EngineService::start(cfg);

    // Cold, cacheable, no sink: completes with no rows, caches nothing.
    let (w0, _h0) = counting_flow(2000, false, 2);
    let bare = svc
        .run(Submission::new(TenantId(0), w0).with_config(Config::for_tests()).cacheable(0xD1CE))
        .expect("admission");
    assert!(!bare.cache_hit);
    assert!(bare.rows.is_empty(), "no sink, no rows");
    assert!(svc.cache().is_empty(), "a sink-less job must not populate the cache");

    // Same plan + salt, now with a sink: must be a cold run with real
    // rows, not a hit serving the sink-less job's empty set.
    let (w1, h1) = counting_flow(2000, false, 2);
    let cold = svc
        .run(
            Submission::new(TenantId(1), w1)
                .with_sink(h1.clone())
                .with_config(Config::for_tests())
                .cacheable(0xD1CE),
        )
        .expect("admission");
    assert!(!cold.cache_hit, "empty cache entry must not exist");
    let expected = result_rows(&cold.rows);
    assert!(!expected.is_empty());

    // Now the cache is populated; a third identical submission hits
    // and gets the full row set.
    let (w2, _h2) = counting_flow(2000, false, 2);
    let warm = svc
        .run(Submission::new(TenantId(2), w2).with_config(Config::for_tests()).cacheable(0xD1CE))
        .expect("admission");
    assert!(warm.cache_hit);
    assert_eq!(result_rows(&warm.rows), expected, "hit must serve the cold run's rows");
}

/// Growing a running job past its tenant's worker share — via
/// `scale_job` or a `Replan` migration — is refused: the share bounds
/// a tenant's footprint for its whole lifetime, not just at admission.
#[test]
fn scale_up_cannot_exceed_tenant_worker_share() {
    use texera_amber::engine::PlanDelta;

    let mut cfg = ServiceConfig::for_tests();
    cfg.engine.max_workers = 8;
    // floor(0.375 * 8) = 3 = the 3-op job's minimum footprint, so the
    // job admits exactly at its allowance with zero headroom.
    cfg.default_quota = TenantQuota { max_worker_share: 0.375, ..TenantQuota::default() };
    let svc = EngineService::start(cfg);

    let (w, h) = slow_flow(500, 2000);
    let id = svc
        .submit(Submission::new(TenantId(0), w).with_sink(h))
        .expect("admission");
    assert!(
        !svc.scale_job(id, 1, 2),
        "scale-up past the tenant worker share must be refused"
    );
    assert!(
        !svc.migrate_job(id, PlanDelta::Replan { workers: vec![(1, 2)] }),
        "Replan growth past the tenant worker share must be refused"
    );
    assert_eq!(svc.ledger().tenant_used(TenantId(0)), 3, "footprint unchanged");
    svc.cancel(id);
    let r = svc.wait(id).expect("known");
    assert!(r.cancelled);
}

/// Results are deliver-once: the first `wait` hands out the rows and
/// evicts the job's entry, so the service does not retain every result
/// forever; a second `wait` on the same id reports unknown.
#[test]
fn wait_delivers_once_and_evicts_the_job() {
    let svc = EngineService::start(ServiceConfig::for_tests());
    let (w, h) = counting_flow(500, false, 1);
    let id = svc
        .submit(Submission::new(TenantId(0), w).with_sink(h))
        .expect("admission");
    let r = svc.wait(id).expect("first wait delivers");
    assert!(r.error.is_none() && !r.rows.is_empty());
    assert!(svc.wait(id).is_none(), "second wait must find the job evicted");
    assert!(!svc.cancel(id), "evicted job is unknown to cancel");
}

//! Batch/tuple equivalence regression tests: the batch-at-a-time data
//! plane must produce sink results identical (same multiset) to the
//! per-tuple path at any batch size, and keep the paper's sub-second
//! pause guarantee (§2.4) at large batches.

use std::time::Duration;

use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, Workflow};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{
    AggKind, CollectSink, CountByKeySink, GroupByFinal, GroupByPartial, HashJoin, SinkHandle,
};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// filter → join (broadcast build) → two-layer group-by → sink.
///
/// * probe: (i, i % 20) for i in 0..4000, filtered to i < 3000;
/// * build: (k, k * 100) for k in 0..20, broadcast to every join
///   worker (exercising the zero-copy fan-out path);
/// * join on k, then SUM(k * 100) grouped by k.
fn run_workflow(batch_size: usize, ctrl_check_interval: usize) -> Vec<(i64, f64)> {
    let mut w = Workflow::new();
    let build_scan = w.add(OpSpec::source("dim_scan", 1, |idx, parts| {
        let rows: Vec<Tuple> = (0..20i64)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(k * 100)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let probe_scan = w.add(OpSpec::source("probe_scan", 2, |idx, parts| {
        let rows: Vec<Tuple> = (0..4000usize)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 20) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Lt, Value::Int(3000)))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        3,
        [PartitionScheme::Broadcast, PartitionScheme::Hash { key: 1 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 1)),
    ));
    let partial = w.add(OpSpec::unary(
        "gb_partial",
        2,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(GroupByPartial::new(0, 1, AggKind::Sum)),
    ));
    let fin = w.add(
        OpSpec::unary("gb_final", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(build_scan, join, 0);
    w.connect(probe_scan, filter, 0);
    w.connect(filter, join, 1);
    w.connect(join, partial, 0);
    w.connect(partial, fin, 0);
    w.connect(fin, sink, 0);

    let cfg = Config {
        batch_size,
        ctrl_check_interval,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    exec.join();
    let mut rows: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    rows.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rows
}

#[test]
fn sink_results_identical_across_batch_sizes() {
    // Expected: keys 0..20, each hit by 150 filtered probe tuples, so
    // SUM(k * 100) = 150 * k * 100.
    let expected: Vec<(i64, f64)> =
        (0..20i64).map(|k| (k, (150 * k * 100) as f64)).collect();
    let per_tuple = run_workflow(1, 1);
    assert_eq!(per_tuple, expected, "per-tuple reference run is wrong");
    for (batch, interval) in [(32usize, 32usize), (1024, 256)] {
        let got = run_workflow(batch, interval);
        assert_eq!(
            got, per_tuple,
            "batch_size={batch} interval={interval} diverged from per-tuple results"
        );
    }
}

/// Skewed hash shuffle: 95% of tuples carry one hot key, so most
/// chunks route to a single destination and the exchange's single-run
/// zero-copy path carries the bulk of the traffic, while the cold keys
/// scatter through selection vectors. The sink multiset must be
/// byte-identical to the per-tuple path at every batch size.
#[test]
fn skewed_hash_shuffle_identical_across_batch_sizes() {
    fn run(batch_size: usize, ctrl_check_interval: usize) -> Vec<(i64, i64)> {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let rows: Vec<Tuple> = (0..60_000usize)
                .skip(idx)
                .step_by(parts)
                .map(|i| {
                    let key = if i % 20 != 0 { 0 } else { (i % 50) as i64 + 1 };
                    Tuple::new(vec![Value::Int(key), Value::Int(i as i64)])
                })
                .collect();
            Box::new(VecSource::new(rows))
        }));
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary(
            "sink",
            4,
            PartitionScheme::Hash { key: 0 },
            move |_, _| Box::new(CollectSink::new(h2.clone())),
        ));
        w.connect(scan, sink, 0);
        let exec = Execution::start(
            w,
            Config { batch_size, ctrl_check_interval, ..Config::default() },
        );
        exec.join();
        let mut rows: Vec<(i64, i64)> = handle
            .tuples()
            .iter()
            .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_int().unwrap()))
            .collect();
        rows.sort_unstable();
        rows
    }
    let per_tuple = run(1, 1);
    assert_eq!(per_tuple.len(), 60_000);
    for (batch, interval) in [(32usize, 32usize), (1024, 1024)] {
        assert_eq!(
            run(batch, interval),
            per_tuple,
            "batch_size={batch} interval={interval} diverged on the skewed shuffle"
        );
    }
}

#[test]
fn sub_second_pause_at_batch_1024() {
    let total = 400_000usize;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..total)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64), Value::Int((i % 10) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary("filter", 4, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(0, Cmp::Ge, Value::Int(0)))
    }));
    let handle = SinkHandle::new(10);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CountByKeySink::new(h2.clone(), 1))
    }));
    w.connect(scan, filter, 0);
    w.connect(filter, sink, 0);

    let cfg = Config {
        batch_size: 1024,
        ctrl_check_interval: 1024,
        ..Config::default()
    };
    let exec = Execution::start(w, cfg);
    std::thread::sleep(Duration::from_millis(20));
    let latency = exec.pause();
    assert!(
        latency < Duration::from_secs(1),
        "pause took {latency:?} at batch 1024 (paper: sub-second)"
    );
    // Output stops while paused (modulo already-buffered batches).
    let at_pause = handle.total();
    std::thread::sleep(Duration::from_millis(100));
    let drained = handle.total();
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(
        handle.total(),
        drained,
        "sink kept growing while paused (started at {at_pause})"
    );
    exec.resume();
    exec.join();
    assert_eq!(handle.total() as usize, total);
}

//! Universal elasticity (engine::scale): the three formerly
//! refusal-only operator classes — **sources** (splittable scan
//! ranges), **scatter-merge** operators (epoch-keyed EOF peer barrier)
//! and **broadcast-input** operators (build-side replication) — scale
//! up and down mid-run with byte-identical sink multisets vs an
//! unscaled run, sub-second fences at batch 1024, and recovery from a
//! checkpoint taken across a source-scale epoch re-deploys at the
//! post-scale parallelism.

use std::time::Duration;
use texera_amber::config::Config;
use texera_amber::engine::{Execution, OpSpec, PartitionScheme, WorkerId, Workflow};
use texera_amber::operators::basic::{Cmp, Filter, MapUdf};
use texera_amber::operators::group_by::{AggKind, GroupByFinal};
use texera_amber::operators::sort::{SortMerge, SortWorker};
use texera_amber::operators::{CollectSink, HashJoin, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::util::Rng;
use texera_amber::workloads::VecSource;

fn seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

fn config() -> Config {
    Config {
        batch_size: 1024,
        ctrl_check_interval: 1024,
        ..Config::default()
    }
}

/// Canonical sorted (key, value) pairs from a sink.
fn kv_result(handle: &SinkHandle) -> Vec<(i64, f64)> {
    let mut out: Vec<(i64, f64)> = handle
        .tuples()
        .iter()
        .map(|t| (t.get(0).as_int().unwrap(), t.get(1).as_float().unwrap()))
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

// ---------------------------------------------------------------- sources

const SRC_ROWS: usize = 600_000;
const SRC_KEYS: i64 = 97;

/// scan(`scan_workers`) → filter(2, costed) → group-by-sum(2, hash) →
/// sink(1). The *scan* is the scaled operator here.
fn source_wf(scan_workers: usize) -> (Workflow, usize, SinkHandle) {
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", scan_workers, move |idx, parts| {
        let rows: Vec<Tuple> = (0..SRC_ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                Tuple::new(vec![
                    Value::Int(i as i64 % SRC_KEYS),
                    Value::Int(i as i64 % 10),
                ])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let filter = w.add(OpSpec::unary(
        "filter",
        2,
        PartitionScheme::RoundRobin,
        |_, _| {
            let mut f = Filter::new(1, Cmp::Ne, Value::Int(0));
            // Keeps the run long enough that the scale point is
            // genuinely mid-run.
            f.cost_ns = 800;
            Box::new(f)
        },
    ));
    let gb = w.add(
        OpSpec::unary("group_by", 2, PartitionScheme::Hash { key: 0 }, |_, _| {
            Box::new(GroupByFinal::new(AggKind::Sum))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, filter, 0);
    w.connect(filter, gb, 0);
    w.connect(gb, sink, 0);
    (w, scan, handle)
}

fn source_reference() -> Vec<(i64, f64)> {
    let mut expect = std::collections::HashMap::new();
    for i in 0..SRC_ROWS {
        let (k, v) = (i as i64 % SRC_KEYS, i as i64 % 10);
        if v != 0 {
            *expect.entry(k).or_insert(0.0) += v as f64;
        }
    }
    let mut out: Vec<(i64, f64)> = expect.into_iter().collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

fn scaled_source_run(from: usize, to: usize, delay_ms: u64) -> (Vec<(i64, f64)>, Duration) {
    let (w, scan, handle) = source_wf(from);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(delay_ms));
    let fence = exec.scale_operator(scan, to);
    exec.join();
    (kv_result(&handle), fence)
}

#[test]
fn source_scale_up_2_to_4_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0x50c1);
    let reference = source_reference();
    // Unscaled run sanity check.
    let (w, _, handle) = source_wf(2);
    Execution::start(w, config()).join();
    assert_eq!(kv_result(&handle), reference, "unscaled source run wrong");

    let (scaled, fence) = scaled_source_run(2, 4, 20 + rng.below(100));
    assert!(
        fence > Duration::ZERO,
        "source scale was refused — run finished before the scale point?"
    );
    assert!(
        fence < Duration::from_secs(1),
        "source-scale fence took {fence:?} (≥1s) at batch 1024"
    );
    assert_eq!(scaled, reference, "2→4 source scale changed the sink multiset");
}

#[test]
fn source_scale_down_4_to_2_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0x50c2);
    let reference = source_reference();
    let (scaled, fence) = scaled_source_run(4, 2, 20 + rng.below(100));
    assert!(fence > Duration::ZERO, "source scale was refused");
    assert!(fence < Duration::from_secs(1), "fence took {fence:?}");
    assert_eq!(scaled, reference, "4→2 source scale changed the sink multiset");
}

// ---------------------------------------------------------- scatter-merge

const SORT_ROWS: usize = 200_000;

/// scan(2) → range-sort(`sort_workers`, scatter-merge) → merge(1) →
/// sink(1). The *sort* (scatter-merge class) is the scaled operator.
/// Single-field tuples, so the merged order is deterministic even
/// among equal keys.
fn sort_wf(sort_workers: usize) -> (Workflow, usize, SinkHandle) {
    let bounds: Vec<Value> = (1..sort_workers as i64)
        .map(|i| Value::Int(i * 1000 / sort_workers as i64))
        .collect();
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..SORT_ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(((i * 37) % 1000) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let b = bounds.clone();
    let sortw = w.add(
        OpSpec::unary(
            "sort",
            sort_workers,
            PartitionScheme::Range { key: 0, bounds },
            move |idx, _| {
                Box::new(SortWorker::new(0, idx as u64, b.clone()).with_cost(3000))
            },
        )
        .with_blocking(vec![0])
        .with_scatter_merge(),
    );
    let merge = w.add(
        OpSpec::unary("merge", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(SortMerge::new(0))
        })
        .with_blocking(vec![0]),
    );
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, sortw, 0);
    w.connect(sortw, merge, 0);
    w.connect(merge, sink, 0);
    (w, sortw, handle)
}

fn sort_output(handle: &SinkHandle) -> Vec<i64> {
    handle
        .tuples()
        .iter()
        .map(|t| t.get(0).as_int().unwrap())
        .collect()
}

fn sort_reference() -> Vec<i64> {
    let mut v: Vec<i64> = (0..SORT_ROWS).map(|i| ((i * 37) % 1000) as i64).collect();
    v.sort_unstable();
    v
}

#[test]
fn scatter_merge_scale_up_2_to_4_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0x5ca1);
    let reference = sort_reference();
    let (w, sortw, handle) = sort_wf(2);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(10 + rng.below(50)));
    let fence = exec.scale_operator(sortw, 4);
    exec.join();
    assert!(
        fence > Duration::ZERO,
        "scatter-merge scale was refused — run finished early?"
    );
    assert!(fence < Duration::from_secs(1), "fence took {fence:?}");
    assert_eq!(
        sort_output(&handle),
        reference,
        "2→4 scatter-merge scale changed the sorted output"
    );
}

#[test]
fn scatter_merge_scale_down_4_to_2_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0x5ca2);
    let reference = sort_reference();
    let (w, sortw, handle) = sort_wf(4);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(10 + rng.below(50)));
    let fence = exec.scale_operator(sortw, 2);
    exec.join();
    assert!(fence > Duration::ZERO, "scatter-merge scale was refused");
    assert!(fence < Duration::from_secs(1), "fence took {fence:?}");
    assert_eq!(
        sort_output(&handle),
        reference,
        "4→2 scatter-merge scale changed the sorted output"
    );
}

// -------------------------------------------------------- broadcast-input

const JOIN_ROWS: usize = 200_000;
const JOIN_KEYS: i64 = 61;

/// dim(1) ──Broadcast──▶ join(`join_workers`) ◀──RR── scan(2); join →
/// sink(1). The *join* (broadcast-input class) is the scaled operator.
fn bcast_wf(join_workers: usize) -> (Workflow, usize, SinkHandle) {
    let mut w = Workflow::new();
    let dim = w.add(OpSpec::source("dim", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..JOIN_KEYS)
            .filter(|k| (*k as usize) % parts == idx)
            .map(|k| Tuple::new(vec![Value::Int(k), Value::Int(k * 3)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
        let rows: Vec<Tuple> = (0..JOIN_ROWS)
            .skip(idx)
            .step_by(parts)
            .map(|i| {
                Tuple::new(vec![
                    Value::Int(i as i64 % JOIN_KEYS),
                    Value::Int(i as i64 % 11),
                ])
            })
            .collect();
        Box::new(VecSource::new(rows))
    }));
    let join = w.add(OpSpec::binary(
        "join",
        join_workers,
        [PartitionScheme::Broadcast, PartitionScheme::RoundRobin],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).with_probe_cost(3000)),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(dim, join, 0);
    w.connect(scan, join, 1);
    w.connect(join, sink, 0);
    (w, join, handle)
}

/// Join output rows as sortable quadruples (build ⋈ probe).
fn join_result(handle: &SinkHandle) -> Vec<(i64, i64, i64, i64)> {
    let mut out: Vec<(i64, i64, i64, i64)> = handle
        .tuples()
        .iter()
        .map(|t| {
            (
                t.get(0).as_int().unwrap(),
                t.get(1).as_int().unwrap(),
                t.get(2).as_int().unwrap(),
                t.get(3).as_int().unwrap(),
            )
        })
        .collect();
    out.sort_unstable();
    out
}

fn join_reference() -> Vec<(i64, i64, i64, i64)> {
    let mut expect: Vec<(i64, i64, i64, i64)> = (0..JOIN_ROWS)
        .map(|i| {
            let (k, v) = (i as i64 % JOIN_KEYS, i as i64 % 11);
            (k, k * 3, k, v)
        })
        .collect();
    expect.sort_unstable();
    expect
}

#[test]
fn broadcast_join_scale_up_2_to_4_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0xbca1);
    let reference = join_reference();
    let (w, join, handle) = bcast_wf(2);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(10 + rng.below(80)));
    let fence = exec.scale_operator(join, 4);
    exec.join();
    assert!(
        fence > Duration::ZERO,
        "broadcast-input scale was refused — run finished early?"
    );
    assert!(fence < Duration::from_secs(1), "fence took {fence:?}");
    assert_eq!(
        join_result(&handle),
        reference,
        "2→4 broadcast-join scale changed the sink multiset"
    );
}

#[test]
fn broadcast_join_scale_down_4_to_2_exact_and_subsecond() {
    let mut rng = Rng::new(seed() ^ 0xbca2);
    let reference = join_reference();
    let (w, join, handle) = bcast_wf(4);
    let exec = Execution::start(w, config());
    std::thread::sleep(Duration::from_millis(10 + rng.below(80)));
    let fence = exec.scale_operator(join, 2);
    exec.join();
    assert!(fence > Duration::ZERO, "broadcast-input scale was refused");
    assert!(fence < Duration::from_secs(1), "fence took {fence:?}");
    assert_eq!(
        join_result(&handle),
        reference,
        "4→2 broadcast-join scale changed the sink multiset"
    );
}

// ----------------------------------------- recovery across a source scale

#[test]
fn recovery_across_source_scale_redeploys_at_post_scale_parallelism() {
    let cfg = Config { ft_log: true, ..Config::default() };
    let reference = source_reference();
    let (w, scan, handle) = source_wf(2);
    let exec = Execution::start(w, cfg.clone());
    std::thread::sleep(Duration::from_millis(30));
    // Scale the source mid-run, then checkpoint *across* the epoch.
    let fence = exec.scale_operator(scan, 4);
    assert!(fence > Duration::ZERO, "source scale was refused");
    std::thread::sleep(Duration::from_millis(10));
    let checkpoint = exec.checkpoint();
    // The checkpoint records the post-scale worker set, each scan
    // worker with its live (re-cut) range embedded as a fork.
    assert!(
        checkpoint.workers.contains_key(&WorkerId::new(scan, 3)),
        "checkpoint did not capture the post-scale scan workers"
    );
    // Crash a worker and abandon the execution.
    exec.crash_workers(vec![WorkerId::new(1, 0)]);
    let log = exec.take_replay_log();
    drop(exec);
    drop(handle);

    // Recover into a workflow declared at the *post-scale* parallelism;
    // the snapshot-embedded forks replace the plan-time ranges, so the
    // recomputation is byte-identical to the damaged run's remainder.
    let (w2, _, handle2) = source_wf(4);
    let recovered = Execution::recover(w2, cfg, checkpoint, log);
    recovered.join();
    assert_eq!(
        kv_result(&handle2),
        reference,
        "recovery across a source-scale epoch lost or duplicated rows"
    );
}

// -------------------------------------------------- ownership/veto guard

/// Regression test for the AutoscalePlugin-vs-driver conflict (ROADMAP
/// PR-4 remaining): once the driver (Maestro's re-planner in
/// production) scales an operator, the autoscale plugin's requests for
/// it are vetoed — the count cannot be silently overwritten by the
/// queue-driven policy (last-writer-wins).
#[test]
fn driver_scale_vetoes_autoscale_plugin_for_same_operator() {
    use texera_amber::engine::AutoscalePlugin;

    let rows = 40_000usize;
    let mut w = Workflow::new();
    let scan = w.add(OpSpec::source("scan", 1, move |idx, parts| {
        let data: Vec<Tuple> = (0..rows)
            .skip(idx)
            .step_by(parts)
            .map(|i| Tuple::new(vec![Value::Int(i as i64)]))
            .collect();
        Box::new(VecSource::new(data))
    }));
    let udf = w.add(OpSpec::unary(
        "udf",
        1,
        PartitionScheme::RoundRobin,
        |_, _| Box::new(MapUdf::identity(20_000)),
    ));
    let handle = SinkHandle::new(0);
    let h = handle.clone();
    let sink = w.add(OpSpec::unary(
        "sink",
        1,
        PartitionScheme::RoundRobin,
        move |_, _| Box::new(CollectSink::new(h.clone())),
    ));
    w.connect(scan, udf, 0);
    w.connect(udf, sink, 0);
    let cfg = Config {
        batch_size: 64,
        autoscale_high_queue: 64.0,
        autoscale_sustain_ticks: 3,
        ..Config::default()
    };
    // An aggressive plugin that would otherwise double the saturated
    // operator's workers (see elastic_scaling.rs, where it does).
    let plugin = AutoscalePlugin::new(udf, 1, 4);
    let exec = Execution::start_with_plugin(w, cfg, Box::new(plugin));
    // Claim the operator for the driver before the plugin's sustain
    // window (3 × 20 ms ticks) can possibly elapse.
    std::thread::sleep(Duration::from_millis(15));
    let fence = exec.scale_operator(udf, 3);
    assert!(fence > Duration::ZERO, "driver scale was refused");
    let summary = exec.join();
    assert_eq!(handle.total() as usize, rows, "run lost tuples");
    // The driver's count survived: exactly workers {0,1,2} completed —
    // the plugin's later double/halve requests were vetoed.
    let udf_workers: std::collections::HashSet<usize> = summary
        .worker_stats
        .iter()
        .filter(|(id, _)| id.op == udf)
        .map(|(id, _)| id.idx)
        .collect();
    assert_eq!(
        udf_workers,
        [0usize, 1, 2].into_iter().collect(),
        "autoscale plugin overrode the driver-owned worker count"
    );
}

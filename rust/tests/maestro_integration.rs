//! Maestro end-to-end: region scheduling on the Ch. 4 climate-analysis
//! workflow shape — cyclic region graph, materialization choice, region
//! order, first-response-time measurement.

use texera_amber::config::Config;
use texera_amber::engine::{OpSpec, PartitionScheme, Workflow};
use texera_amber::maestro::cost::CostParams;
use texera_amber::maestro::{enumerate_choices, MaestroScheduler};
use texera_amber::operators::basic::{Cmp, Filter};
use texera_amber::operators::{CollectSink, HashJoin, SinkHandle};
use texera_amber::tuple::{Tuple, Value};
use texera_amber::workloads::VecSource;

/// Fig. 4.2-style wildfire workflow slice: zipcode history replicated
/// into both the build input and (via a filter) the probe input of a
/// strict join — a cyclic region graph that needs materialization.
fn climate_workflow(zipcodes: usize) -> (Workflow, SinkHandle, usize, Vec<usize>) {
    let mut w = Workflow::new();
    // History scan: (zipcode, fire_count).
    let hist = w.add(OpSpec::source("scan_history", 1, move |idx, parts| {
        let rows: Vec<Tuple> = (0..zipcodes)
            .filter(|i| i % parts == idx)
            .map(|z| Tuple::new(vec![Value::Int(z as i64), Value::Int((z % 7) as i64)]))
            .collect();
        Box::new(VecSource::new(rows))
    }));
    // Filter zipcodes with fires → build side.
    let filt = w.add(OpSpec::unary("filter_fires", 1, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Gt, Value::Int(0)))
    }));
    // Probe side: the same scan through a pass-all filter.
    let before = w.add(OpSpec::unary("before_filter", 1, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
    }));
    let j1 = w.add(OpSpec::binary(
        "join_before",
        2,
        [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
        vec![0],
        |_, _| Box::new(HashJoin::new(0, 0).strict()),
    ));
    let handle = SinkHandle::new(0);
    let h2 = handle.clone();
    let sink = w.add(OpSpec::unary("bar_chart", 1, PartitionScheme::RoundRobin, move |_, _| {
        Box::new(CollectSink::new(h2.clone()))
    }));
    w.connect(hist, filt, 0);
    w.connect(filt, j1, 0);
    w.connect(hist, before, 0);
    w.connect(before, j1, 1);
    w.connect(j1, sink, 0);
    (w, handle, sink, vec![hist, filt, before, j1])
}

#[test]
fn cyclic_workflow_scheduled_with_strict_join() {
    let (w, handle, sink, _) = climate_workflow(100);
    let mut cost = CostParams::new();
    cost.source_rows.insert(0, 100.0);
    let sched = MaestroScheduler::new(Config::for_tests(), cost);
    let outcome = sched.run(w, &[sink]);
    assert!(!outcome.choice.is_empty(), "materialization was required");
    // Join output: zipcodes with fires (z%7>0) joined against all 100
    // probe rows with the same zipcode.
    let expect = (0..100).filter(|z| z % 7 > 0).count() as u64;
    assert_eq!(handle.total(), expect, "strict join lost tuples");
    assert!(outcome.measured_frt.is_finite());
}

#[test]
fn all_choices_produce_identical_results() {
    // Result correctness is independent of the materialization choice;
    // only timing/size change (§4.5).
    let mut totals = Vec::new();
    let (w0, _, sink, _) = climate_workflow(60);
    let choices = enumerate_choices(&w0, 2);
    assert!(choices.len() >= 2, "want multiple choices, got {choices:?}");
    for c in &choices {
        let (w, handle, sink2, _) = climate_workflow(60);
        assert_eq!(sink, sink2);
        let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
        let outcome = sched.run_with_choice(w, &[sink2], c, 0.0);
        totals.push((handle.total(), outcome.mat_bytes.iter().sum::<u64>()));
    }
    let first = totals[0].0;
    for (t, _) in &totals {
        assert_eq!(*t, first, "results differ across choices: {totals:?}");
    }
    // Some choice materializes a nonzero volume.
    assert!(totals.iter().any(|(_, b)| *b > 0));
}

#[test]
fn estimated_frt_ranks_choices() {
    let (w, _, sink, ops) = climate_workflow(200);
    let mut cost = CostParams::new();
    cost.source_rows.insert(ops[0], 200.0);
    let choices = enumerate_choices(&w, 2);
    let mut est: Vec<(Vec<usize>, f64)> = Vec::new();
    for c in &choices {
        let (frt, _) =
            texera_amber::maestro::first_response_time(&w, c, &cost, &[sink]);
        assert!(frt.is_finite() && frt > 0.0);
        est.push((c.clone(), frt));
    }
    est.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    assert!(est[0].1 <= est[est.len() - 1].1);
}

#[test]
fn larger_input_larger_materialization() {
    // Figs. 4.23/4.24: materialized bytes grow with input size.
    let mut sizes = Vec::new();
    for n in [50usize, 100, 200] {
        let (w, _, sink, _) = climate_workflow(n);
        let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
        let outcome = sched.run(w, &[sink]);
        sizes.push(outcome.mat_bytes.iter().sum::<u64>());
    }
    assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2], "{sizes:?}");
}

#[test]
fn region_order_is_valid_permutation() {
    let (w, _, sink, _) = climate_workflow(80);
    let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
    let outcome = sched.run(w, &[sink]);
    let mut seen = outcome.region_order.clone();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), outcome.region_order.len());
    assert!(outcome.region_order.len() >= 2);
}

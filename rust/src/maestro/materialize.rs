//! Materialization of a pipelined link (§4.4.3): replace edge u→v with
//! `u → MatWriter` and `MatReader → v`, making the link blocking at
//! the writer boundary so the regions split.
//!
//! The writer appends tuples to a shared buffer (tracking bytes for
//! Figs. 4.23/4.24); the reader is a dormant source activated when its
//! region is scheduled — by then the writer's region has completed and
//! the buffer is final.
//!
//! A finished store doubles as an **observation point** for the elastic
//! scheduler: [`MatStore::rows`] is the exact cardinality entering the
//! reader's region and [`MatStore::mean_bytes_per_tuple`] the measured
//! tuple width, both fed back into
//! [`CostParams`](crate::maestro::cost::CostParams) when the remaining
//! regions are re-planned.
//!
//! **Out-of-core** (see `docs/ARCHITECTURE.md` "Out-of-core
//! execution"): past the execution's memory budget the store flushes
//! its resident tail to sequential append-only **chunk files** in the
//! execution's spill directory; logical row ids are stable across the
//! chunk list + resident tail, so [`MatSource`]'s strided id-space
//! mapping (and its `fork`/`split` re-cuts) is unaffected. Each reader
//! scans chunks through a windowed cursor that buffers one spill frame
//! at a time. `bytes`/`rows` keep counting *logical* content wherever
//! it lives, so the scheduler's observation feedback is unchanged.

use crate::engine::dag::{OpSpec, Workflow};
use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::PartitionScheme;
use crate::engine::spill::{MemLease, SpillCtx, SpillFile, SpillReader, SpillSlot};
use crate::tuple::Tuple;
use crate::workloads::TupleSource;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Spill-slot tag: a store has one stream kind — appended chunks.
const TAG_CHUNK: u32 = 0;

/// Rows per spill frame when flushing a chunk: bounds the window a
/// reader cursor holds in memory.
const CHUNK_FRAME_ROWS: usize = 512;

struct MatInner {
    /// Resident tail: logical rows `[resident_base, resident_base + len)`.
    resident: Vec<Tuple>,
    resident_bytes: u64,
    /// Logical row index of `resident[0]`.
    resident_base: usize,
    /// Flushed chunks in write order; `chunk_starts[i]` is the logical
    /// row index of `chunks[i]`'s first row.
    chunks: Vec<SpillSlot>,
    chunk_starts: Vec<usize>,
    ctx: Option<SpillCtx>,
    lease: MemLease,
}

impl Default for MatInner {
    fn default() -> MatInner {
        MatInner {
            resident: Vec::new(),
            resident_bytes: 0,
            resident_base: 0,
            chunks: Vec::new(),
            chunk_starts: Vec::new(),
            ctx: None,
            lease: MemLease::default(),
        }
    }
}

impl MatInner {
    fn rows(&self) -> usize {
        self.resident_base + self.resident.len()
    }

    /// Flush the resident tail to one new chunk file when over budget.
    fn maybe_spill(&mut self) {
        let Some(ctx) = self.ctx.clone() else { return };
        self.lease.set(self.resident_bytes);
        if !ctx.budget.over() || self.resident.is_empty() {
            return;
        }
        let seq = self.chunks.len() as u64;
        let mut f = SpillFile::create(&ctx, TAG_CHUNK, 0, seq);
        for frame in self.resident.chunks(CHUNK_FRAME_ROWS) {
            f.append(frame);
        }
        ctx.counters.add_partition();
        self.chunk_starts.push(self.resident_base);
        self.resident_base += self.resident.len();
        self.resident.clear();
        self.resident_bytes = 0;
        self.chunks.push(f.slot());
        self.lease.set(0);
    }

    /// Read every chunk back in write order (sequential scan).
    fn read_chunks(&self) -> Vec<Tuple> {
        let Some(ctx) = &self.ctx else { return Vec::new() };
        let mut out = Vec::new();
        for slot in &self.chunks {
            out.extend(crate::engine::spill::read_slot_rows(ctx, slot));
        }
        out
    }
}

/// Windowed cursor over one reader's sequential walk of the chunk
/// list: holds one decoded spill frame; advancing to a later row in
/// the same chunk streams forward, anything else re-opens.
struct ChunkCursor {
    chunk: usize,
    reader: SpillReader,
    /// Logical row index of `window[0]`.
    start: usize,
    window: Vec<Tuple>,
}

/// Shared store backing one materialized link.
#[derive(Clone, Default)]
pub struct MatStore {
    inner: Arc<Mutex<MatInner>>,
    /// Total *logical* bytes appended (resident + spilled): the cost
    /// model's observation point, independent of where rows live.
    bytes: Arc<AtomicU64>,
}

impl MatStore {
    pub fn new() -> MatStore {
        MatStore::default()
    }

    /// Enable disk backing. First caller wins — every writer worker of
    /// one execution shares the same [`SpillCtx`], so this is
    /// idempotent in practice.
    pub fn attach_spill(&self, ctx: &SpillCtx) {
        let mut inner = self.inner.lock().unwrap();
        if inner.ctx.is_none() {
            inner.lease = MemLease::new(ctx.budget.clone());
            inner.ctx = Some(ctx.clone());
        }
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> usize {
        self.inner.lock().unwrap().rows()
    }

    /// Bytes currently flushed to chunk files (0 while fully resident).
    pub fn spilled_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.chunks.iter().map(|c| c.bytes).sum()
    }

    /// Drain the full store contents, resetting the byte counter. Used
    /// by live mat *removal* ([`crate::engine::migrate`]): the rows
    /// captured so far are re-injected into the restored direct edge.
    /// Spilled chunks are read back in write order; their files stay
    /// on disk, orphaned, until the execution's spill directory is
    /// reclaimed at teardown.
    pub fn take_all(&self) -> Vec<Tuple> {
        self.bytes.store(0, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let mut rows = inner.read_chunks();
        rows.append(&mut inner.resident);
        inner.chunks.clear();
        inner.chunk_starts.clear();
        inner.resident_base = 0;
        inner.resident_bytes = 0;
        inner.lease.set(0);
        rows
    }

    /// Bulk-load rows, updating the byte counter. The serving layer's
    /// fingerprint cache (`crate::service::fingerprint`) stores a
    /// completed job's sink rows this way for cross-workflow reuse.
    pub fn append_rows(&self, rows: Vec<Tuple>) {
        let sz: u64 = rows.iter().map(|t| t.byte_size() as u64).sum();
        self.bytes.fetch_add(sz, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.resident_bytes += sz;
        inner.resident.extend(rows);
        inner.maybe_spill();
    }

    /// Copy of the store contents without draining — cache reads must
    /// leave the entry in place for the next tenant.
    pub fn snapshot(&self) -> Vec<Tuple> {
        let inner = self.inner.lock().unwrap();
        let mut rows = inner.read_chunks();
        rows.extend(inner.resident.iter().cloned());
        rows
    }

    /// Observed average tuple width in bytes (`None` until the store
    /// holds rows) — re-planning feeds this back into
    /// [`CostParams::bytes_per_tuple`](crate::maestro::cost::CostParams).
    pub fn mean_bytes_per_tuple(&self) -> Option<f64> {
        let rows = self.rows();
        if rows == 0 {
            None
        } else {
            Some(self.bytes() as f64 / rows as f64)
        }
    }

    /// Logical row `i`, wherever it lives. `cursor` is the calling
    /// reader's chunk window — forward strides within a chunk stream
    /// from the open reader; chunk changes and backward seeks re-open.
    fn row_at(&self, i: usize, cursor: &mut Option<ChunkCursor>) -> Option<Tuple> {
        let inner = self.inner.lock().unwrap();
        if i >= inner.resident_base {
            return inner.resident.get(i - inner.resident_base).cloned();
        }
        let ctx = inner.ctx.as_ref().expect("spilled rows imply an attached ctx");
        // Locate the chunk containing logical row i.
        let c = match inner.chunk_starts.binary_search(&i) {
            Ok(c) => c,
            Err(ins) => ins - 1,
        };
        let reusable = cursor
            .as_ref()
            .is_some_and(|cur| cur.chunk == c && i >= cur.start);
        if !reusable {
            *cursor = Some(ChunkCursor {
                chunk: c,
                reader: SpillReader::open(ctx, &inner.chunks[c]),
                start: inner.chunk_starts[c],
                window: Vec::new(),
            });
        }
        let cur = cursor.as_mut().unwrap();
        while i >= cur.start + cur.window.len() {
            cur.start += cur.window.len();
            match cur.reader.next_rows() {
                Some(rows) => cur.window = rows,
                None => return None,
            }
        }
        Some(cur.window[i - cur.start].clone())
    }
}

/// Sink-side operator of a materialized link.
pub struct MatWriter {
    store: MatStore,
    buffer: Vec<Tuple>,
}

impl MatWriter {
    pub fn new(store: MatStore) -> MatWriter {
        MatWriter { store, buffer: Vec::new() }
    }
}

impl Operator for MatWriter {
    fn name(&self) -> &str {
        "mat_writer"
    }

    fn attach_spill(&mut self, ctx: &SpillCtx) {
        self.store.attach_spill(ctx);
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        self.store
            .bytes
            .fetch_add(t.byte_size() as u64, Ordering::Relaxed);
        self.buffer.push(t);
        if self.buffer.len() >= 1024 {
            self.flush();
        }
    }

    fn finish(&mut self, _out: &mut dyn Emitter) {
        self.flush();
    }

    fn state_size(&self) -> usize {
        self.buffer.len()
    }

    /// Unflushed tail of the write buffer, surrendered when a live mat
    /// is removed mid-run so the tuples re-enter the restored edge
    /// (they never reached the shared store; their bytes are deducted
    /// since they no longer pass through it).
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        let sz: u64 = self.buffer.iter().map(|t| t.byte_size() as u64).sum();
        self.store.bytes.fetch_sub(sz, Ordering::Relaxed);
        if self.buffer.is_empty() {
            Vec::new()
        } else {
            vec![(0, std::mem::take(&mut self.buffer))]
        }
    }
}

impl MatWriter {
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let sz: u64 = self.buffer.iter().map(|t| t.byte_size() as u64).sum();
        let mut inner = self.store.inner.lock().unwrap();
        inner.resident_bytes += sz;
        inner.resident.append(&mut self.buffer);
        inner.maybe_spill();
    }
}

/// Source-side of a materialized link: partition `idx` of `parts`
/// reads rows `i ≡ idx (mod parts)` from the store.
pub struct MatSource {
    store: MatStore,
    parts: usize,
    idx: usize,
    pos: usize,
    cursor: Option<ChunkCursor>,
}

impl MatSource {
    pub fn new(store: MatStore, parts: usize, idx: usize) -> MatSource {
        MatSource { store, parts, idx, pos: 0, cursor: None }
    }
}

impl TupleSource for MatSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let i = self.idx + self.pos * self.parts;
        let t = self.store.row_at(i, &mut self.cursor);
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn reset(&mut self) {
        self.pos = 0;
        self.cursor = None;
    }

    fn len_hint(&self) -> Option<usize> {
        let total = self.store.rows();
        let (p, i) = (self.parts, self.idx);
        Some(if i >= total { 0 } else { (total - i + p - 1) / p })
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
        self.cursor = None;
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(MatSource {
            store: self.store.clone(),
            parts: self.parts,
            idx: self.idx,
            pos: self.pos,
            cursor: None,
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        // Stride re-cut over the shared store. Valid even while the
        // store is still being written (a dormant reader being scaled
        // before its writer region completed): the id-space mapping is
        // independent of the store's current length — and of how much
        // of it has been flushed to chunk files.
        Some(
            (0..n)
                .map(|j| {
                    Box::new(MatSource {
                        store: self.store.clone(),
                        parts: self.parts * n,
                        idx: self.idx + (self.pos + j) * self.parts,
                        pos: 0,
                        cursor: None,
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// Result of applying a materialization choice.
pub struct Materialized {
    pub workflow: Workflow,
    /// One store per materialized edge (same order as the choice).
    pub stores: Vec<MatStore>,
    /// Reader operator index per materialized edge.
    pub readers: Vec<usize>,
    /// Writer operator index per materialized edge.
    pub writers: Vec<usize>,
    /// (writer, reader) pairs: each is an ordering constraint — the
    /// writer's region must complete before the reader's region starts
    /// (the reader consumes the finished store). The region graph must
    /// include these as dependency edges.
    pub links: Vec<(usize, usize)>,
}

/// Rewrite `w`, materializing the given edge indices.
pub fn apply_choice(w: &Workflow, choice: &[usize]) -> Materialized {
    let mut out = Workflow { ops: w.ops.clone(), edges: Vec::new() };
    let mut stores = Vec::new();
    let mut readers = Vec::new();
    let mut writers = Vec::new();
    for (ei, e) in w.edges.iter().enumerate() {
        if !choice.contains(&ei) {
            out.edges.push(*e);
            continue;
        }
        let store = MatStore::new();
        let workers = w.ops[e.from].workers;
        let s2 = store.clone();
        let writer = out.add(OpSpec::unary(
            &format!("mat_writer_{ei}"),
            workers,
            PartitionScheme::OneToOne,
            move |_, _| Box::new(MatWriter::new(s2.clone())),
        ));
        let s3 = store.clone();
        let reader = out.add(OpSpec::source(
            &format!("mat_reader_{ei}"),
            workers,
            move |idx, parts| Box::new(MatSource::new(s3.clone(), parts, idx)),
        ));
        out.edges.push(crate::engine::dag::Edge { from: e.from, to: writer, to_port: 0 });
        out.edges.push(crate::engine::dag::Edge { from: reader, to: e.to, to_port: e.to_port });
        stores.push(store);
        readers.push(reader);
        writers.push(writer);
    }
    let links = writers.iter().copied().zip(readers.iter().copied()).collect();
    Materialized { workflow: out, stores, readers, writers, links }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::tuple::Value;

    #[test]
    fn writer_reader_roundtrip() {
        let store = MatStore::new();
        let mut w = MatWriter::new(store.clone());
        let mut out = crate::engine::operator::VecEmitter::default();
        for i in 0..10 {
            w.process(Tuple::new(vec![Value::Int(i)]), 0, &mut out);
        }
        w.finish(&mut out);
        assert_eq!(store.rows(), 10);
        assert!(store.bytes() > 0);
        let mut r = MatSource::new(store, 2, 1);
        let got: Vec<i64> = std::iter::from_fn(|| r.next_tuple())
            .map(|t| t.get(0).as_int().unwrap())
            .collect();
        assert_eq!(got, vec![1, 3, 5, 7, 9]);
    }

    #[test]
    fn apply_choice_splits_edge() {
        use crate::engine::dag::OpSpec;
        use crate::engine::partitioner::PartitionScheme;
        use crate::workloads::VecSource;
        struct Noop;
        impl Operator for Noop {
            fn name(&self) -> &str {
                "noop"
            }
            fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
                out.emit(t);
            }
        }
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f = w.add(OpSpec::unary("f", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f, 0);
        let m = apply_choice(&w, &[0]);
        assert_eq!(m.workflow.ops.len(), 4);
        assert_eq!(m.workflow.edges.len(), 2);
        assert!(m.workflow.validate().is_ok());
        // New region boundary: writer has no out-edges within a
        // pipelined path to f.
        let regions = crate::maestro::region::regions_of(&m.workflow);
        assert_eq!(regions.len(), 2);
    }

    // ---- out-of-core ----

    fn tiny_ctx(limit: u64) -> SpillCtx {
        let mut cfg = Config::for_tests();
        cfg.memory_budget_bytes = limit;
        SpillCtx::new(&cfg)
    }

    #[test]
    fn spilled_store_reads_back_identically() {
        let plain = MatStore::new();
        let spilled = MatStore::new();
        let ctx = tiny_ctx(512);
        spilled.attach_spill(&ctx);
        let rows: Vec<Tuple> = (0..500)
            .map(|i| Tuple::new(vec![Value::Int(i), Value::str(&format!("row{i}"))]))
            .collect();
        // Append in small batches so the budget trips repeatedly.
        for chunk in rows.chunks(32) {
            plain.append_rows(chunk.to_vec());
            spilled.append_rows(chunk.to_vec());
        }
        assert_eq!(spilled.rows(), plain.rows());
        assert_eq!(spilled.bytes(), plain.bytes(), "logical bytes unchanged by spilling");
        assert!(spilled.spilled_bytes() > 0, "tiny budget must flush chunks");
        assert_eq!(spilled.snapshot(), plain.snapshot());
        // Strided readers see the same partitions.
        for idx in 0..3 {
            let mut a = MatSource::new(plain.clone(), 3, idx);
            let mut b = MatSource::new(spilled.clone(), 3, idx);
            let va: Vec<Tuple> = std::iter::from_fn(|| a.next_tuple()).collect();
            let vb: Vec<Tuple> = std::iter::from_fn(|| b.next_tuple()).collect();
            assert_eq!(va, vb, "reader {idx} of 3");
        }
        // take_all drains chunks + resident in order.
        assert_eq!(spilled.take_all(), rows);
        assert_eq!(spilled.rows(), 0);
        assert_eq!(spilled.bytes(), 0);
    }

    #[test]
    fn spilled_reader_seek_and_split() {
        let store = MatStore::new();
        let ctx = tiny_ctx(256);
        store.attach_spill(&ctx);
        for i in 0..300 {
            store.append_rows(vec![Tuple::new(vec![Value::Int(i)])]);
        }
        let mut r = MatSource::new(store.clone(), 1, 0);
        for _ in 0..100 {
            r.next_tuple();
        }
        // Backward seek re-opens the window.
        r.seek(10);
        assert_eq!(r.next_tuple().unwrap().get(0).as_int(), Some(10));
        // Split re-cuts the id space across chunks + resident alike.
        let mut parts = r.split(2).unwrap();
        let a: Vec<i64> = std::iter::from_fn(|| parts[0].next_tuple())
            .map(|t| t.get(0).as_int().unwrap())
            .collect();
        let b: Vec<i64> = std::iter::from_fn(|| parts[1].next_tuple())
            .map(|t| t.get(0).as_int().unwrap())
            .collect();
        let mut all: Vec<i64> = a.into_iter().chain(b).collect();
        all.sort_unstable();
        assert_eq!(all, (11..300).collect::<Vec<i64>>());
    }
}

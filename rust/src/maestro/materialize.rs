//! Materialization of a pipelined link (§4.4.3): replace edge u→v with
//! `u → MatWriter` and `MatReader → v`, making the link blocking at
//! the writer boundary so the regions split.
//!
//! The writer appends tuples to a shared buffer (tracking bytes for
//! Figs. 4.23/4.24); the reader is a dormant source activated when its
//! region is scheduled — by then the writer's region has completed and
//! the buffer is final.
//!
//! A finished store doubles as an **observation point** for the elastic
//! scheduler: [`MatStore::rows`] is the exact cardinality entering the
//! reader's region and [`MatStore::mean_bytes_per_tuple`] the measured
//! tuple width, both fed back into
//! [`CostParams`](crate::maestro::cost::CostParams) when the remaining
//! regions are re-planned.

use crate::engine::dag::{OpSpec, Workflow};
use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::PartitionScheme;
use crate::tuple::Tuple;
use crate::workloads::TupleSource;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared store backing one materialized link.
#[derive(Clone, Default)]
pub struct MatStore {
    data: Arc<Mutex<Vec<Tuple>>>,
    bytes: Arc<AtomicU64>,
}

impl MatStore {
    pub fn new() -> MatStore {
        MatStore::default()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> usize {
        self.data.lock().unwrap().len()
    }

    /// Drain the full store contents, resetting the byte counter. Used
    /// by live mat *removal* ([`crate::engine::migrate`]): the rows
    /// captured so far are re-injected into the restored direct edge.
    pub fn take_all(&self) -> Vec<Tuple> {
        self.bytes.store(0, Ordering::Relaxed);
        std::mem::take(&mut *self.data.lock().unwrap())
    }

    /// Bulk-load rows, updating the byte counter. The serving layer's
    /// fingerprint cache (`crate::service::fingerprint`) stores a
    /// completed job's sink rows this way for cross-workflow reuse.
    pub fn append_rows(&self, rows: Vec<Tuple>) {
        let sz: u64 = rows.iter().map(|t| t.byte_size() as u64).sum();
        self.bytes.fetch_add(sz, Ordering::Relaxed);
        self.data.lock().unwrap().extend(rows);
    }

    /// Copy of the store contents without draining — cache reads must
    /// leave the entry in place for the next tenant.
    pub fn snapshot(&self) -> Vec<Tuple> {
        self.data.lock().unwrap().clone()
    }

    /// Observed average tuple width in bytes (`None` until the store
    /// holds rows) — re-planning feeds this back into
    /// [`CostParams::bytes_per_tuple`](crate::maestro::cost::CostParams).
    pub fn mean_bytes_per_tuple(&self) -> Option<f64> {
        let rows = self.rows();
        if rows == 0 {
            None
        } else {
            Some(self.bytes() as f64 / rows as f64)
        }
    }
}

/// Sink-side operator of a materialized link.
pub struct MatWriter {
    store: MatStore,
    buffer: Vec<Tuple>,
}

impl MatWriter {
    pub fn new(store: MatStore) -> MatWriter {
        MatWriter { store, buffer: Vec::new() }
    }
}

impl Operator for MatWriter {
    fn name(&self) -> &str {
        "mat_writer"
    }

    fn process(&mut self, t: Tuple, _port: usize, _out: &mut dyn Emitter) {
        self.store
            .bytes
            .fetch_add(t.byte_size() as u64, Ordering::Relaxed);
        self.buffer.push(t);
        if self.buffer.len() >= 1024 {
            self.store.data.lock().unwrap().append(&mut self.buffer);
        }
    }

    fn finish(&mut self, _out: &mut dyn Emitter) {
        self.store.data.lock().unwrap().append(&mut self.buffer);
    }

    fn state_size(&self) -> usize {
        self.buffer.len()
    }

    /// Unflushed tail of the write buffer, surrendered when a live mat
    /// is removed mid-run so the tuples re-enter the restored edge
    /// (they never reached the shared store; their bytes are deducted
    /// since they no longer pass through it).
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        let sz: u64 = self.buffer.iter().map(|t| t.byte_size() as u64).sum();
        self.store.bytes.fetch_sub(sz, Ordering::Relaxed);
        if self.buffer.is_empty() {
            Vec::new()
        } else {
            vec![(0, std::mem::take(&mut self.buffer))]
        }
    }
}

/// Source-side of a materialized link: partition `idx` of `parts`
/// reads rows `i ≡ idx (mod parts)` from the store.
pub struct MatSource {
    store: MatStore,
    parts: usize,
    idx: usize,
    pos: usize,
}

impl MatSource {
    pub fn new(store: MatStore, parts: usize, idx: usize) -> MatSource {
        MatSource { store, parts, idx, pos: 0 }
    }
}

impl TupleSource for MatSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let i = self.idx + self.pos * self.parts;
        let guard = self.store.data.lock().unwrap();
        let t = guard.get(i).cloned();
        drop(guard);
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn len_hint(&self) -> Option<usize> {
        let total = self.store.rows();
        let (p, i) = (self.parts, self.idx);
        Some(if i >= total { 0 } else { (total - i + p - 1) / p })
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(MatSource {
            store: self.store.clone(),
            parts: self.parts,
            idx: self.idx,
            pos: self.pos,
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        // Stride re-cut over the shared store. Valid even while the
        // store is still being written (a dormant reader being scaled
        // before its writer region completed): the id-space mapping is
        // independent of the store's current length.
        Some(
            (0..n)
                .map(|j| {
                    Box::new(MatSource {
                        store: self.store.clone(),
                        parts: self.parts * n,
                        idx: self.idx + (self.pos + j) * self.parts,
                        pos: 0,
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// Result of applying a materialization choice.
pub struct Materialized {
    pub workflow: Workflow,
    /// One store per materialized edge (same order as the choice).
    pub stores: Vec<MatStore>,
    /// Reader operator index per materialized edge.
    pub readers: Vec<usize>,
    /// Writer operator index per materialized edge.
    pub writers: Vec<usize>,
    /// (writer, reader) pairs: each is an ordering constraint — the
    /// writer's region must complete before the reader's region starts
    /// (the reader consumes the finished store). The region graph must
    /// include these as dependency edges.
    pub links: Vec<(usize, usize)>,
}

/// Rewrite `w`, materializing the given edge indices.
pub fn apply_choice(w: &Workflow, choice: &[usize]) -> Materialized {
    let mut out = Workflow { ops: w.ops.clone(), edges: Vec::new() };
    let mut stores = Vec::new();
    let mut readers = Vec::new();
    let mut writers = Vec::new();
    for (ei, e) in w.edges.iter().enumerate() {
        if !choice.contains(&ei) {
            out.edges.push(*e);
            continue;
        }
        let store = MatStore::new();
        let workers = w.ops[e.from].workers;
        let s2 = store.clone();
        let writer = out.add(OpSpec::unary(
            &format!("mat_writer_{ei}"),
            workers,
            PartitionScheme::OneToOne,
            move |_, _| Box::new(MatWriter::new(s2.clone())),
        ));
        let s3 = store.clone();
        let reader = out.add(OpSpec::source(
            &format!("mat_reader_{ei}"),
            workers,
            move |idx, parts| Box::new(MatSource::new(s3.clone(), parts, idx)),
        ));
        out.edges.push(crate::engine::dag::Edge { from: e.from, to: writer, to_port: 0 });
        out.edges.push(crate::engine::dag::Edge { from: reader, to: e.to, to_port: e.to_port });
        stores.push(store);
        readers.push(reader);
        writers.push(writer);
    }
    let links = writers.iter().copied().zip(readers.iter().copied()).collect();
    Materialized { workflow: out, stores, readers, writers, links }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Value;

    #[test]
    fn writer_reader_roundtrip() {
        let store = MatStore::new();
        let mut w = MatWriter::new(store.clone());
        let mut out = crate::engine::operator::VecEmitter::default();
        for i in 0..10 {
            w.process(Tuple::new(vec![Value::Int(i)]), 0, &mut out);
        }
        w.finish(&mut out);
        assert_eq!(store.rows(), 10);
        assert!(store.bytes() > 0);
        let mut r = MatSource::new(store, 2, 1);
        let got: Vec<i64> = std::iter::from_fn(|| r.next_tuple())
            .map(|t| t.get(0).as_int().unwrap())
            .collect();
        assert_eq!(got, vec![1, 3, 5, 7, 9]);
    }

    #[test]
    fn apply_choice_splits_edge() {
        use crate::engine::dag::OpSpec;
        use crate::engine::partitioner::PartitionScheme;
        use crate::workloads::VecSource;
        struct Noop;
        impl Operator for Noop {
            fn name(&self) -> &str {
                "noop"
            }
            fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
                out.emit(t);
            }
        }
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f = w.add(OpSpec::unary("f", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f, 0);
        let m = apply_choice(&w, &[0]);
        assert_eq!(m.workflow.ops.len(), 4);
        assert_eq!(m.workflow.edges.len(), 2);
        assert!(m.workflow.validate().is_ok());
        // New region boundary: writer has no out-edges within a
        // pipelined path to f.
        let regions = crate::maestro::region::regions_of(&m.workflow);
        assert_eq!(regions.len(), 2);
    }
}

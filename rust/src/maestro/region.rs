//! Regions (§4.4.1): maximal sub-DAGs connected by *pipelined* links.
//!
//! Removing every blocking link from the workflow graph and taking
//! connected components (undirected, over the remaining pipelined
//! links) yields the regions: within a region data flows without
//! barriers; blocking links order regions against each other. An
//! operator whose inputs are all blocking (e.g. `GroupByFinal`) starts
//! a new region together with its pipelined downstream.

use crate::engine::dag::Workflow;

/// A region: operator indices plus contained (pipelined) edge indices.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Region {
    pub id: usize,
    pub ops: Vec<usize>,
    /// Indices into `workflow.edges` of pipelined links inside the
    /// region.
    pub edges: Vec<usize>,
}

impl Region {
    pub fn contains(&self, op: usize) -> bool {
        self.ops.contains(&op)
    }
}

/// Split a workflow into regions.
pub fn regions_of(w: &Workflow) -> Vec<Region> {
    let n = w.ops.len();
    // Union-find over operators via pipelined edges.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        if parent[x] != x {
            let r = find(parent, parent[x]);
            parent[x] = r;
        }
        parent[x]
    }
    for e in &w.edges {
        if !w.is_blocking_edge(e) {
            let (a, b) = (find(&mut parent, e.from), find(&mut parent, e.to));
            if a != b {
                parent[a] = b;
            }
        }
    }
    // Group ops by root, in deterministic order.
    let mut root_to_region: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for op in 0..n {
        let r = find(&mut parent, op);
        root_to_region.entry(r).or_default().push(op);
    }
    let mut regions: Vec<Region> = root_to_region
        .into_values()
        .enumerate()
        .map(|(id, ops)| Region { id, ops, edges: Vec::new() })
        .collect();
    // Assign pipelined edges to their region.
    for (ei, e) in w.edges.iter().enumerate() {
        if !w.is_blocking_edge(e) {
            for r in regions.iter_mut() {
                if r.contains(e.from) {
                    r.edges.push(ei);
                    break;
                }
            }
        }
    }
    regions
}

/// Region id containing operator `op`.
pub fn region_of(regions: &[Region], op: usize) -> usize {
    regions
        .iter()
        .find(|r| r.contains(op))
        .map(|r| r.id)
        .expect("operator not in any region")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::{OpSpec, Workflow};
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn src(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::source(name, 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }))
    }

    fn unary(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }))
    }

    fn unary_blocking(w: &mut Workflow, name: &str) -> usize {
        w.add(
            OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
                .with_blocking(vec![0]),
        )
    }

    /// scan → filter → groupby(blocking) → sink: two regions split at
    /// the blocking link (like Fig. 4.6).
    #[test]
    fn blocking_link_splits_regions() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f = unary(&mut w, "filter");
        let g = unary_blocking(&mut w, "groupby");
        let k = unary(&mut w, "sink");
        w.connect(s, f, 0);
        w.connect(f, g, 0);
        w.connect(g, k, 0);
        let regions = regions_of(&w);
        assert_eq!(regions.len(), 2);
        assert_eq!(region_of(&regions, s), region_of(&regions, f));
        assert_eq!(region_of(&regions, g), region_of(&regions, k));
        assert_ne!(region_of(&regions, f), region_of(&regions, g));
    }

    /// Join: build link blocking, probe pipelined → join sits in the
    /// probe's region (Fig. 4.7).
    #[test]
    fn join_in_probe_region() {
        let mut w = Workflow::new();
        let b = src(&mut w, "build_scan");
        let p = src(&mut w, "probe_scan");
        let j = w.add(OpSpec::binary(
            "join",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ));
        let k = unary(&mut w, "sink");
        w.connect(b, j, 0);
        w.connect(p, j, 1);
        w.connect(j, k, 0);
        let regions = regions_of(&w);
        assert_eq!(regions.len(), 2);
        assert_eq!(region_of(&regions, p), region_of(&regions, j));
        assert_eq!(region_of(&regions, j), region_of(&regions, k));
        assert_ne!(region_of(&regions, b), region_of(&regions, j));
    }

    #[test]
    fn fully_pipelined_single_region() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f = unary(&mut w, "filter");
        let k = unary(&mut w, "sink");
        w.connect(s, f, 0);
        w.connect(f, k, 0);
        let regions = regions_of(&w);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].ops.len(), 3);
        assert_eq!(regions[0].edges.len(), 2);
    }

    #[test]
    fn edges_assigned_to_owning_region() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let g = unary_blocking(&mut w, "groupby");
        let k = unary(&mut w, "sink");
        w.connect(s, g, 0); // blocking: belongs to no region
        w.connect(g, k, 0); // pipelined: belongs to g's region
        let regions = regions_of(&w);
        let total_edges: usize = regions.iter().map(|r| r.edges.len()).sum();
        assert_eq!(total_edges, 1);
    }
}

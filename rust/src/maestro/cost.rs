//! First-response-time cost model (§4.5.3–4.5.4).
//!
//! For a materialization choice c applied to a workflow, the first
//! response time is
//!
//! ```text
//! FRT(c) = Σ_{r ∈ ancestors(sink region)} time(r) + ε_first(sink region)
//! ```
//!
//! — every region the sink region (transitively) depends on must fully
//! execute, then the sink region only needs to produce a single tuple
//! (Fig. 4.13). Region time is modeled from per-operator cardinality
//! and per-tuple cost estimates divided by worker parallelism, plus
//! per-byte materialization write/read costs on the region's
//! materialized boundaries (Fig. 4.14 extends this to the several
//! sink-containing regions; we take the minimum when multiple sinks
//! exist).

use crate::engine::dag::Workflow;
use crate::maestro::materialize::apply_choice;
use crate::maestro::region::region_of;
use std::collections::HashMap;

/// Cardinality / cost annotations for the model.
#[derive(Clone, Debug, Default)]
pub struct CostParams {
    /// Rows produced by each source operator.
    pub source_rows: HashMap<usize, f64>,
    /// Output/input selectivity per operator (default 1.0).
    pub selectivity: HashMap<usize, f64>,
    /// Per-tuple processing cost per operator (default 1.0).
    pub tuple_cost: HashMap<usize, f64>,
    /// Average bytes per tuple (materialization sizing; default 64).
    pub bytes_per_tuple: f64,
    /// Cost per byte written+read at a materialized boundary.
    pub mat_byte_cost: f64,
}

impl CostParams {
    pub fn new() -> CostParams {
        CostParams { bytes_per_tuple: 64.0, mat_byte_cost: 0.01, ..Default::default() }
    }

    fn sel(&self, op: usize) -> f64 {
        self.selectivity.get(&op).copied().unwrap_or(1.0)
    }

    fn cost(&self, op: usize) -> f64 {
        self.tuple_cost.get(&op).copied().unwrap_or(1.0)
    }
}

/// Estimated rows flowing *out of* each operator (topological pass).
/// Multi-input operators emit the sum of inputs times selectivity.
pub fn cardinalities(w: &Workflow, p: &CostParams) -> Vec<f64> {
    let mut rows_out = vec![0.0f64; w.ops.len()];
    let order = w.topo_order();
    for &op in &order {
        let rows_in: f64 = if w.ops[op].is_source {
            p.source_rows.get(&op).copied().unwrap_or(1000.0)
        } else {
            w.in_edges(op).iter().map(|e| rows_out[e.from]).sum()
        };
        rows_out[op] = rows_in * p.sel(op);
    }
    rows_out
}

/// Per-operator work: rows_in · cost / workers.
fn op_work(w: &Workflow, p: &CostParams, rows_out: &[f64], op: usize) -> f64 {
    let rows_in: f64 = if w.ops[op].is_source {
        p.source_rows.get(&op).copied().unwrap_or(1000.0)
    } else {
        w.in_edges(op).iter().map(|e| rows_out[e.from]).sum()
    };
    rows_in * p.cost(op) / w.ops[op].workers.max(1) as f64
}

/// First response time of the workflow after materializing `choice`.
/// Also returns the total materialized bytes (the Figs. 4.23/4.24
/// metric). `sink_ops` are the result operators to measure (first
/// tuple out of any of them).
pub fn first_response_time(
    w: &Workflow,
    choice: &[usize],
    p: &CostParams,
    sink_ops: &[usize],
) -> (f64, f64) {
    let m = apply_choice(w, choice);
    let mw = &m.workflow;
    let g = crate::maestro::region_graph::region_graph_ext(mw, &m.links);
    let rows_out = cardinalities(mw, p);
    // Estimated materialized bytes: rows entering each writer.
    let mat_bytes: f64 = m
        .writers
        .iter()
        .map(|&wr| {
            let rows: f64 = mw.in_edges(wr).iter().map(|e| rows_out[e.from]).sum();
            rows * p.bytes_per_tuple
        })
        .sum();
    // Region execution times (full completion).
    let region_time: Vec<f64> = g
        .regions
        .iter()
        .map(|r| {
            let mut t: f64 = r.ops.iter().map(|&op| op_work(mw, p, &rows_out, op)).sum();
            // Materialization IO inside this region: writers add write
            // cost; readers add read cost.
            for &wr in &m.writers {
                if r.contains(wr) {
                    let rows: f64 =
                        mw.in_edges(wr).iter().map(|e| rows_out[e.from]).sum();
                    t += rows * p.bytes_per_tuple * p.mat_byte_cost;
                }
            }
            for &rd in &m.readers {
                if r.contains(rd) {
                    t += rows_out[rd] * p.bytes_per_tuple * p.mat_byte_cost;
                }
            }
            t
        })
        .collect();
    // FRT per sink: ancestors fully execute; the sink region produces
    // one tuple (ε — modeled as the region's pipeline latency: one
    // tuple through each op, negligible vs region times; we charge the
    // per-tuple cost chain).
    let mut best = f64::INFINITY;
    for &sink in sink_ops {
        let rs = region_of(&g.regions, sink);
        let ancestors = g.ancestors(rs);
        let mut t: f64 = ancestors.iter().map(|&r| region_time[r]).sum();
        // Single-tuple latency through the sink region's operator chain.
        t += g.regions[rs]
            .ops
            .iter()
            .map(|&op| p.cost(op))
            .sum::<f64>();
        best = best.min(t);
    }
    (best, mat_bytes)
}

/// Pick the choice minimizing FRT (ties → smaller materialized bytes).
pub fn best_choice(
    w: &Workflow,
    choices: &[Vec<usize>],
    p: &CostParams,
    sink_ops: &[usize],
) -> (usize, f64, f64) {
    let mut best = (0usize, f64::INFINITY, f64::INFINITY);
    for (i, c) in choices.iter().enumerate() {
        let (frt, bytes) = first_response_time(w, c, p, sink_ops);
        if frt < best.1 || (frt == best.1 && bytes < best.2) {
            best = (i, frt, bytes);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn fig_4_1() -> (Workflow, usize) {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f1 = w.add(OpSpec::unary("filter1", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let f2 = w.add(OpSpec::unary("filter2", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let j = w.add(OpSpec::binary(
            "join",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f1, 0); // e0
        w.connect(s, f2, 0); // e1 → build path
        w.connect(f2, j, 0); // e2 blocking
        w.connect(f1, j, 1); // e3 probe
        w.connect(j, k, 0); // e4
        (w, k)
    }

    #[test]
    fn cardinalities_flow_through() {
        let (w, _) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 1000.0);
        p.selectivity.insert(1, 0.5);
        let rows = cardinalities(&w, &p);
        assert_eq!(rows[0], 1000.0);
        assert_eq!(rows[1], 500.0);
        assert_eq!(rows[2], 1000.0);
        // Join sums its inputs (conservative).
        assert_eq!(rows[3], 1500.0);
    }

    #[test]
    fn frt_prefers_materializing_small_side() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        // filter2 (build path) is very selective → materializing the
        // small build side (e1 after filter2… here e1 is pre-filter; the
        // comparable choice is e0-vs-e1 with f2 selective): choice {e1}
        // materializes 10k rows; {e0} also 10k. Make f1 selective
        // instead so the probe path shrinks.
        p.selectivity.insert(2, 0.01); // filter2 keeps 1%
        let choices = vec![vec![0usize], vec![1usize]];
        let (frt0, bytes0) = first_response_time(&w, &choices[0], &p, &[sink]);
        let (frt1, bytes1) = first_response_time(&w, &choices[1], &p, &[sink]);
        // Materializing e0 (probe raw feed) forces the whole probe feed
        // into an ancestor region; materializing e1 defers only the
        // build feed. Both materialize 10k rows here, but the ancestor
        // work differs: with {e1}, the ancestor region includes the
        // probe chain too? Regions: with {e1}: region A = {scan, f1,
        // writer}… the sink region contains j,k and depends on A and
        // the f2-chain region. With {e0}: similar shape. The FRTs
        // must at least be finite, positive and distinguishable.
        assert!(frt0.is_finite() && frt1.is_finite());
        assert!(frt0 > 0.0 && frt1 > 0.0);
        assert_eq!(bytes0, bytes1); // same rows materialized pre-filter
    }

    #[test]
    fn best_choice_minimizes_frt() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        p.tuple_cost.insert(1, 10.0); // filter1 expensive
        let choices = crate::maestro::enumerate_choices(&w, 2);
        let (idx, frt, bytes) = best_choice(&w, &choices, &p, &[sink]);
        assert!(idx < choices.len());
        assert!(frt.is_finite());
        assert!(bytes > 0.0);
        // Exhaustive check: no other choice strictly better.
        for c in &choices {
            let (f, _) = first_response_time(&w, c, &p, &[sink]);
            assert!(f >= frt - 1e-9);
        }
    }

    #[test]
    fn already_feasible_zero_ancestor_cost() {
        // scan → sink: FRT is just the single-tuple latency.
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        let p = CostParams::new();
        let (frt, bytes) = first_response_time(&w, &[], &p, &[k]);
        assert_eq!(bytes, 0.0);
        assert!(frt <= 3.0, "pipelined FRT should be tiny, got {frt}");
        let _ = s;
    }
}

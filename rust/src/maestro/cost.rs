//! First-response-time cost model (§4.5.3–4.5.4), **worker-aware**.
//!
//! For a materialization choice c applied to a workflow, the first
//! response time is
//!
//! ```text
//! FRT(c, n⃗) = Σ_{r ∈ ancestors(sink region)} time(r, n⃗) + ε_first(sink region)
//! ```
//!
//! — every region the sink region (transitively) depends on must fully
//! execute, then the sink region only needs to produce a single tuple
//! (Fig. 4.13). Region time is modeled from per-operator cardinality
//! and per-tuple cost estimates divided by the operator's **worker
//! count** n⃗, plus per-byte materialization write/read costs on the
//! region's materialized boundaries.
//!
//! Two things make the model *elastic* and *result-aware*:
//!
//! 1. **Joint planning.** [`best_choice_elastic`] searches over
//!    (materialization choice × per-region worker assignment) pairs: for
//!    every enumerated choice it calls [`assign_workers`], which
//!    distributes a cluster-wide budget ([`Config::max_workers`]) over
//!    each region's operators by greedy marginal gain — one worker at a
//!    time to the operator whose modeled region-time drops the most,
//!    which converges to the square-root allocation n_i ∝ √work_i the
//!    continuous relaxation prescribes. Operators tied by one-to-one
//!    edges (e.g. a `MatWriter` behind its producer) are grouped and
//!    always share one count. The budget applies **per region**, not to
//!    the whole workflow at once — the Maestro schedule is
//!    region-sequential along every dependency chain, though
//!    independent sibling regions may overlap and transiently hold the
//!    budget each.
//!
//! 2. **Observed cardinalities.** [`CostParams::pinned_rows`] overrides
//!    the estimated rows-out of an operator with a measured value. The
//!    scheduler pins every completed operator's actual output (and every
//!    finished `MatStore`'s row count) before re-planning the remaining
//!    regions, so later decisions are driven by data properties observed
//!    at runtime rather than plan-time guesses — the Whiz/F² argument
//!    for decoupling work allocation from the static plan.
//!
//! [`Config::max_workers`]: crate::config::Config::max_workers

use crate::engine::dag::Workflow;
use crate::engine::partitioner::PartitionScheme;
use crate::maestro::materialize::apply_choice;
use crate::maestro::region::{region_of, Region};
use std::collections::HashMap;

/// Cardinality / cost annotations for the model.
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Rows produced by each source operator.
    pub source_rows: HashMap<usize, f64>,
    /// Output/input selectivity per operator (default 1.0).
    pub selectivity: HashMap<usize, f64>,
    /// Per-tuple processing cost per operator (default
    /// [`default_tuple_cost`](Self::default_tuple_cost)).
    pub tuple_cost: HashMap<usize, f64>,
    /// Per-tuple cost for operators without a `tuple_cost` entry
    /// ([`Config::maestro_tuple_cost`](crate::config::Config)).
    pub default_tuple_cost: f64,
    /// Average bytes per tuple (materialization sizing; default 64).
    pub bytes_per_tuple: f64,
    /// Cost per byte written+read at a materialized boundary.
    pub mat_byte_cost: f64,
    /// **Observed** rows-out per operator: overrides the estimate in
    /// [`cardinalities`] and stops selectivity errors from propagating
    /// past a measured point. The scheduler fills this between region
    /// activations from completed operators' `produced` counters and
    /// finished `MatStore`s.
    pub pinned_rows: HashMap<usize, f64>,
    /// Memory budget in bytes (0 = unbounded; mirrors
    /// [`Config::memory_budget_bytes`](crate::config::Config)).
    /// Resident state a region holds beyond this — blocking-input
    /// volume (join builds, group tables, sort runs) plus `MatStore`
    /// volume — is priced as spill traffic in the region time.
    pub memory_budget_bytes: f64,
    /// Cost per byte of state past the budget: one write to the spill
    /// plane plus one read back, combined
    /// ([`Config::maestro_spill_byte_cost`](crate::config::Config)
    /// until [`CostParams::calibrate_spill`] replaces it with the
    /// observed bandwidth).
    pub spill_byte_cost: f64,
}

impl Default for CostParams {
    fn default() -> CostParams {
        CostParams {
            source_rows: HashMap::new(),
            selectivity: HashMap::new(),
            tuple_cost: HashMap::new(),
            default_tuple_cost: 1.0,
            bytes_per_tuple: 64.0,
            mat_byte_cost: 0.01,
            pinned_rows: HashMap::new(),
            memory_budget_bytes: 0.0,
            spill_byte_cost: 0.05,
        }
    }
}

impl CostParams {
    pub fn new() -> CostParams {
        CostParams::default()
    }

    /// Seed the model constants from the engine configuration
    /// (`maestro_tuple_cost` is the default per-tuple cost unit,
    /// `maestro_mat_byte_cost` the materialization IO cost).
    pub fn from_config(config: &crate::config::Config) -> CostParams {
        CostParams {
            default_tuple_cost: config.maestro_tuple_cost,
            mat_byte_cost: config.maestro_mat_byte_cost,
            memory_budget_bytes: config.memory_budget_bytes as f64,
            spill_byte_cost: config.maestro_spill_byte_cost,
            ..Default::default()
        }
    }

    /// Replace the configured spill cost with the bandwidth actually
    /// observed on the spill plane: µs per byte across the write
    /// (encode + flush) and read-back (read + decode) paths combined —
    /// the same µs unit the tuple-cost calibration uses, so spill
    /// pricing and compute pricing stay commensurable after the
    /// scheduler's first re-plan. No-op until any traffic (and time)
    /// has been observed.
    pub fn calibrate_spill(&mut self, stats: &crate::metrics::SpillStats) {
        let bytes = stats.bytes_spilled + stats.bytes_read_back;
        let ns = stats.spill_write_ns + stats.spill_read_ns;
        if bytes == 0 || ns == 0 {
            return;
        }
        self.spill_byte_cost = ns as f64 / bytes as f64 / 1000.0;
    }

    fn sel(&self, op: usize) -> f64 {
        self.selectivity.get(&op).copied().unwrap_or(1.0)
    }

    fn cost(&self, op: usize) -> f64 {
        self.tuple_cost
            .get(&op)
            .copied()
            .unwrap_or(self.default_tuple_cost)
    }
}

/// Estimated rows flowing *out of* each operator (topological pass).
/// Multi-input operators emit the sum of inputs times selectivity; an
/// operator with a [`CostParams::pinned_rows`] entry emits exactly the
/// observed value instead, and downstream estimates build on it.
pub fn cardinalities(w: &Workflow, p: &CostParams) -> Vec<f64> {
    let mut rows_out = vec![0.0f64; w.ops.len()];
    let order = w.topo_order();
    for &op in &order {
        if let Some(&obs) = p.pinned_rows.get(&op) {
            rows_out[op] = obs;
            continue;
        }
        rows_out[op] = rows_in_of(w, p, &rows_out, op) * p.sel(op);
    }
    rows_out
}

/// Rows entering an operator given the rows-out of its upstreams.
fn rows_in_of(w: &Workflow, p: &CostParams, rows_out: &[f64], op: usize) -> f64 {
    if w.ops[op].is_source {
        p.source_rows.get(&op).copied().unwrap_or(1000.0)
    } else {
        w.in_edges(op).iter().map(|e| rows_out[e.from]).sum()
    }
}

/// Per-operator work at parallelism `n`: rows_in · cost / n.
fn op_work_n(w: &Workflow, p: &CostParams, rows_out: &[f64], op: usize, n: usize) -> f64 {
    rows_in_of(w, p, rows_out, op) * p.cost(op) / n.max(1) as f64
}

/// Time to fully execute one region at the given worker counts:
/// per-operator work plus materialization IO on the region's writer
/// and reader boundaries (IO cost is volume-bound, not divided by
/// workers).
fn region_time(
    w: &Workflow,
    p: &CostParams,
    rows_out: &[f64],
    r: &Region,
    workers: &[usize],
    writers: &[usize],
    readers: &[usize],
) -> f64 {
    let mut t: f64 = r
        .ops
        .iter()
        .map(|&op| op_work_n(w, p, rows_out, op, workers[op]))
        .sum();
    for &wr in writers {
        if r.contains(wr) {
            t += rows_in_of(w, p, rows_out, wr) * p.bytes_per_tuple * p.mat_byte_cost;
        }
    }
    for &rd in readers {
        if r.contains(rd) {
            t += rows_out[rd] * p.bytes_per_tuple * p.mat_byte_cost;
        }
    }
    // Out-of-core pricing: resident state past the memory budget is
    // spilled and read back, volume-bound like mat IO (not divided by
    // workers). Choices that pile more state or materialized volume
    // into one region pay for it when memory is tight, which is what
    // steers `best_choice_elastic` away from memory-hungry plans.
    if p.memory_budget_bytes > 0.0 {
        let excess = region_state_bytes(w, p, rows_out, r, writers) - p.memory_budget_bytes;
        if excess > 0.0 {
            t += excess * p.spill_byte_cost;
        }
    }
    t
}

/// Resident-state bytes a region holds at its peak: every blocking
/// input inside the region buffers its full upstream volume (the join
/// build side, group-by tables, sort runs — blocking is exactly the
/// "holds everything before emitting" property), and a mat writer's
/// store holds everything written until its readers drain it.
fn region_state_bytes(
    w: &Workflow,
    p: &CostParams,
    rows_out: &[f64],
    r: &Region,
    writers: &[usize],
) -> f64 {
    let mut bytes: f64 = w
        .edges
        .iter()
        .filter(|e| w.is_blocking_edge(e) && r.contains(e.to))
        .map(|e| rows_out[e.from] * p.bytes_per_tuple)
        .sum();
    for &wr in writers {
        if r.contains(wr) {
            bytes += rows_in_of(w, p, rows_out, wr) * p.bytes_per_tuple;
        }
    }
    bytes
}

/// First response time of the workflow after materializing `choice`,
/// at the workflow's **authored** worker counts. Also returns the total
/// estimated materialized bytes (the Figs. 4.23/4.24 metric).
/// `sink_ops` are the result operators to measure (first tuple out of
/// any of them).
pub fn first_response_time(
    w: &Workflow,
    choice: &[usize],
    p: &CostParams,
    sink_ops: &[usize],
) -> (f64, f64) {
    let m = apply_choice(w, choice);
    let workers: Vec<usize> = m.workflow.ops.iter().map(|o| o.workers).collect();
    frt_of_materialized(&m, p, sink_ops, &workers)
}

/// FRT + estimated materialized bytes of an already-materialized
/// workflow at explicit per-operator worker counts (indexed like
/// `m.workflow.ops`).
pub fn frt_of_materialized(
    m: &crate::maestro::materialize::Materialized,
    p: &CostParams,
    sink_ops: &[usize],
    workers: &[usize],
) -> (f64, f64) {
    let mw = &m.workflow;
    let g = crate::maestro::region_graph::region_graph_ext(mw, &m.links);
    let rows_out = cardinalities(mw, p);
    // Estimated materialized bytes: rows entering each writer.
    let mat_bytes: f64 = m
        .writers
        .iter()
        .map(|&wr| rows_in_of(mw, p, &rows_out, wr) * p.bytes_per_tuple)
        .sum();
    let times: Vec<f64> = g
        .regions
        .iter()
        .map(|r| region_time(mw, p, &rows_out, r, workers, &m.writers, &m.readers))
        .collect();
    // FRT per sink: ancestors fully execute; the sink region produces
    // one tuple (ε — the single-tuple latency through the region's
    // operator chain, negligible against region times).
    let mut best = f64::INFINITY;
    for &sink in sink_ops {
        let rs = region_of(&g.regions, sink);
        let ancestors = g.ancestors(rs);
        let mut t: f64 = ancestors.iter().map(|&r| times[r]).sum();
        t += g.regions[rs].ops.iter().map(|&op| p.cost(op)).sum::<f64>();
        best = best.min(t);
    }
    (best, mat_bytes)
}

/// Pick the choice minimizing FRT at authored worker counts (ties →
/// smaller materialized bytes).
pub fn best_choice(
    w: &Workflow,
    choices: &[Vec<usize>],
    p: &CostParams,
    sink_ops: &[usize],
) -> (usize, f64, f64) {
    let mut best = (0usize, f64::INFINITY, f64::INFINITY);
    for (i, c) in choices.iter().enumerate() {
        let (frt, bytes) = first_response_time(w, c, p, sink_ops);
        if frt < best.1 || (frt == best.1 && bytes < best.2) {
            best = (i, frt, bytes);
        }
    }
    best
}

// ---- elastic planning -------------------------------------------------

/// A joint (materialization, parallelism) plan for one workflow.
#[derive(Clone, Debug)]
pub struct ElasticPlan {
    /// Chosen materialization (edge indices of the original workflow).
    pub choice: Vec<usize>,
    /// Worker count per operator of the **materialized** workflow
    /// (`apply_choice` is deterministic, so indices are stable across
    /// re-application of the same choice).
    pub workers: Vec<usize>,
    /// Estimated FRT at those counts (cost-model units).
    pub estimated_frt: f64,
    /// Estimated rows-out per materialized operator at plan time — kept
    /// so the scheduler's decision trail can report estimate-vs-observed
    /// q-errors.
    pub est_rows: Vec<f64>,
}

/// Operators that must share one worker count: every edge landing on a
/// one-to-one input port forces its endpoints to equal parallelism
/// (worker *i* feeds worker *i*), e.g. a `MatWriter` behind its
/// producer. Returns disjoint groups covering all ops, each sorted,
/// ordered by first member.
pub fn one_to_one_groups(w: &Workflow) -> Vec<Vec<usize>> {
    let n = w.ops.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut [usize], x: usize) -> usize {
        let mut r = x;
        while parent[r] != r {
            r = parent[r];
        }
        let mut c = x;
        while parent[c] != r {
            let next = parent[c];
            parent[c] = r;
            c = next;
        }
        r
    }
    for e in &w.edges {
        let scheme = &w.ops[e.to].input_partitioning[e.to_port];
        if matches!(scheme, PartitionScheme::OneToOne) {
            let (a, b) = (find(&mut parent, e.from), find(&mut parent, e.to));
            if a != b {
                parent[a.max(b)] = a.min(b);
            }
        }
    }
    let mut by_root: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for op in 0..n {
        let r = find(&mut parent, op);
        by_root.entry(r).or_default().push(op);
    }
    by_root.into_values().collect()
}

/// One unit of the greedy marginal-gain allocation: a set of operators
/// forced to share a single worker count (a one-to-one group), with its
/// summed modeled work, a cardinality-derived cap, and an optional pin.
/// Built per region by [`assign_workers`] and per whole workflow by
/// [`workflow_alloc_groups`] (the serving layer's cross-workflow
/// arbiter unit — see `crate::service::arbiter`).
#[derive(Clone, Debug)]
pub struct AllocGroup {
    /// Members sharing the count — one increment costs this many
    /// workers.
    pub members: usize,
    /// Summed modeled work (`rows_in · cost`) across members, possibly
    /// pre-scaled by a priority weight.
    pub work: f64,
    /// Current shared count; the greedy loop grows it in place.
    pub count: usize,
    /// Upper bound on the count (max estimated rows over members — a
    /// 5-row operator gets no 8-way fan-out).
    pub cap: usize,
    /// Whether the loop may grow this group (`false` = pinned).
    pub free: bool,
}

/// Build one [`AllocGroup`] from its member operator list.
fn alloc_group_of(
    w: &Workflow,
    rows_out: &[f64],
    p: &CostParams,
    weight: f64,
    fixed: &HashMap<usize, usize>,
    g: &[usize],
) -> AllocGroup {
    let work: f64 = g
        .iter()
        .map(|&op| rows_in_of(w, p, rows_out, op) * p.cost(op))
        .sum::<f64>()
        * weight;
    let cap = g
        .iter()
        .map(|&op| rows_in_of(w, p, rows_out, op).ceil().max(1.0) as usize)
        .max()
        .unwrap_or(1);
    let pinned = g.iter().find_map(|op| fixed.get(op).copied());
    AllocGroup {
        members: g.len(),
        work,
        count: pinned.unwrap_or(1),
        cap,
        free: pinned.is_none(),
    }
}

/// The greedy marginal-gain loop shared by the per-region
/// [`assign_workers`] and the cross-workflow service arbiter
/// (`crate::service::arbiter::arbitrate`): hand `slots` extra workers
/// out one group at a time, always to the group with the largest
/// marginal drop in modeled time — `work·(1/n − 1/(n+1))/members` —
/// skipping pinned groups, groups at their cap, and groups whose
/// member count exceeds the remaining slots. Deterministic: groups are
/// scanned in index order and only a *strictly* larger gain displaces
/// the incumbent, so equal-gain ties resolve to the earlier group.
/// Counts grow in place; returns the unspent slots.
pub fn greedy_distribute(groups: &mut [AllocGroup], mut slots: usize) -> usize {
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in groups.iter().enumerate() {
            if !g.free || g.count >= g.cap || g.members > slots {
                continue;
            }
            let gain = g.work * (1.0 / g.count as f64 - 1.0 / (g.count + 1) as f64)
                / g.members as f64;
            if best.map(|(_, b)| gain > b).unwrap_or(gain > 0.0) {
                best = Some((i, gain));
            }
        }
        let Some((i, _)) = best else { break };
        slots -= groups[i].members;
        groups[i].count += 1;
    }
    slots
}

/// Allocation groups for one workflow treated as a **single allocation
/// domain** — the serving layer's arbitration unit. Unlike
/// [`assign_workers`], which budgets each region independently
/// (Maestro's schedule is region-sequential), a whole workflow handed
/// to `Execution::start` deploys every worker at once, so the service
/// arbiter charges all its one-to-one groups against one global pool.
/// `weight` uniformly scales each group's modeled work (the priority
/// knob: a uniform scale preserves the greedy's relative gain order,
/// so a single-workflow arbitration at any weight allocates exactly
/// like `assign_workers` on a single-region workflow). Returns
/// `(group, member ops)` pairs in [`one_to_one_groups`] order.
pub fn workflow_alloc_groups(
    w: &Workflow,
    rows_out: &[f64],
    p: &CostParams,
    weight: f64,
    fixed: &HashMap<usize, usize>,
) -> Vec<(AllocGroup, Vec<usize>)> {
    one_to_one_groups(w)
        .into_iter()
        .map(|g| (alloc_group_of(w, rows_out, p, weight, fixed, &g), g))
        .collect()
}

/// Distribute a per-region worker budget over a workflow's operators.
///
/// For each region independently: every one-to-one group starts at one
/// worker per member (or its pinned count from `fixed` — operators
/// whose scale request the engine refused, e.g. their region drained
/// early and workers completed), then spare budget is handed out by
/// [`greedy_distribute`], one group at a time, to the group
/// with the largest marginal drop in modeled region time
/// (`W_g(1/n − 1/(n+1))` per worker slot). A group never grows beyond
/// the rows it is estimated to process — a 5-row operator gets no 8-way
/// fan-out. Groups containing a `fixed` member keep that count.
///
/// Returns one count per operator. The budget is a best-effort cap: if
/// `fixed` counts alone exceed it, the remaining groups still get one
/// worker each.
pub fn assign_workers(
    w: &Workflow,
    regions: &[Region],
    rows_out: &[f64],
    p: &CostParams,
    budget: usize,
    fixed: &HashMap<usize, usize>,
) -> Vec<usize> {
    let mut out: Vec<usize> = w.ops.iter().map(|o| o.workers).collect();
    let groups = one_to_one_groups(w);
    for r in regions {
        // Groups fully inside this region (one-to-one edges are
        // pipelined, so a group never straddles a region boundary).
        // Ordered by first member already (`one_to_one_groups` keys its
        // BTreeMap on the union-find root, which is the min member), so
        // greedy tie-breaks are deterministic.
        let region_groups: Vec<&Vec<usize>> = groups
            .iter()
            .filter(|g| g.iter().all(|op| r.contains(*op)))
            .collect();
        let mut gs: Vec<AllocGroup> = region_groups
            .iter()
            .map(|g| alloc_group_of(w, rows_out, p, 1.0, fixed, g))
            .collect();
        let spent: usize = gs.iter().map(|g| g.count * g.members).sum();
        greedy_distribute(&mut gs, budget.saturating_sub(spent));
        for (g, ops) in gs.iter().zip(&region_groups) {
            for &op in ops.iter() {
                out[op] = g.count;
            }
        }
    }
    out
}

/// Seed mat-reader cardinalities: a reader is a source the base model
/// knows nothing about, so its `source_rows` entry is estimated from
/// the rows *entering the paired writer*, iterated to a fixed point
/// over chained materializations — a wrong guess at the scan then
/// propagates consistently instead of being papered over by the
/// unknown-source default. Readers with a pinned (observed) cardinality
/// are never touched; `skip(writer, reader)` exempts further links
/// (caller-supplied estimates at plan time, finished writers whose
/// exact store counts are already in place at re-plan time).
pub(crate) fn seed_reader_rows(
    m: &crate::maestro::materialize::Materialized,
    p: &mut CostParams,
    mut skip: impl FnMut(usize, usize) -> bool,
) {
    for _ in 0..=m.links.len() {
        let rows = cardinalities(&m.workflow, p);
        for &(writer, reader) in &m.links {
            if p.pinned_rows.contains_key(&reader) || skip(writer, reader) {
                continue;
            }
            let entering = rows_in_of(&m.workflow, p, &rows, writer);
            p.source_rows.insert(reader, entering);
        }
    }
}

/// Build the elastic plan for one materialization choice: assign worker
/// counts under `budget` and evaluate the resulting FRT. Mat-reader
/// cardinalities are seeded via [`seed_reader_rows`], honoring any
/// reader estimate the caller supplied up front.
pub fn plan_for_choice(
    w: &Workflow,
    choice: &[usize],
    p: &CostParams,
    sink_ops: &[usize],
    budget: usize,
    fixed: &HashMap<usize, usize>,
) -> ElasticPlan {
    let m = apply_choice(w, choice);
    let mut p = p.clone();
    let preset: std::collections::HashSet<usize> = m
        .links
        .iter()
        .map(|&(_, reader)| reader)
        .filter(|r| p.source_rows.contains_key(r))
        .collect();
    seed_reader_rows(&m, &mut p, |_, reader| preset.contains(&reader));
    let g = crate::maestro::region_graph::region_graph_ext(&m.workflow, &m.links);
    let rows_out = cardinalities(&m.workflow, &p);
    let workers = assign_workers(&m.workflow, &g.regions, &rows_out, &p, budget, fixed);
    let (frt, _) = frt_of_materialized(&m, &p, sink_ops, &workers);
    ElasticPlan {
        choice: choice.to_vec(),
        workers,
        estimated_frt: frt,
        est_rows: rows_out,
    }
}

/// Jointly pick the (choice, worker assignment) pair with the least
/// estimated FRT under the per-region worker budget. Returns the index
/// of the winning choice and its plan.
pub fn best_choice_elastic(
    w: &Workflow,
    choices: &[Vec<usize>],
    p: &CostParams,
    sink_ops: &[usize],
    budget: usize,
) -> (usize, ElasticPlan) {
    let fixed = HashMap::new();
    let mut best: Option<(usize, ElasticPlan)> = None;
    for (i, c) in choices.iter().enumerate() {
        let plan = plan_for_choice(w, c, p, sink_ops, budget, &fixed);
        if best
            .as_ref()
            .map(|(_, b)| plan.estimated_frt < b.estimated_frt)
            .unwrap_or(true)
        {
            best = Some((i, plan));
        }
    }
    best.expect("no choices given")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn fig_4_1() -> (Workflow, usize) {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f1 = w.add(OpSpec::unary("filter1", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let f2 = w.add(OpSpec::unary("filter2", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let j = w.add(OpSpec::binary(
            "join",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f1, 0); // e0
        w.connect(s, f2, 0); // e1 → build path
        w.connect(f2, j, 0); // e2 blocking
        w.connect(f1, j, 1); // e3 probe
        w.connect(j, k, 0); // e4
        (w, k)
    }

    #[test]
    fn cardinalities_flow_through() {
        let (w, _) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 1000.0);
        p.selectivity.insert(1, 0.5);
        let rows = cardinalities(&w, &p);
        assert_eq!(rows[0], 1000.0);
        assert_eq!(rows[1], 500.0);
        assert_eq!(rows[2], 1000.0);
        // Join sums its inputs (conservative).
        assert_eq!(rows[3], 1500.0);
    }

    #[test]
    fn pinned_rows_override_estimates_and_propagate() {
        let (w, _) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 1000.0);
        p.selectivity.insert(1, 0.5); // estimate says 500…
        p.pinned_rows.insert(1, 900.0); // …observation says 900
        let rows = cardinalities(&w, &p);
        assert_eq!(rows[1], 900.0);
        // The join estimate builds on the observed value.
        assert_eq!(rows[3], 900.0 + 1000.0);
    }

    #[test]
    fn frt_prefers_materializing_small_side() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        p.selectivity.insert(2, 0.01); // filter2 keeps 1%
        let choices = vec![vec![0usize], vec![1usize]];
        let (frt0, bytes0) = first_response_time(&w, &choices[0], &p, &[sink]);
        let (frt1, bytes1) = first_response_time(&w, &choices[1], &p, &[sink]);
        assert!(frt0.is_finite() && frt1.is_finite());
        assert!(frt0 > 0.0 && frt1 > 0.0);
        assert_eq!(bytes0, bytes1); // same rows materialized pre-filter
    }

    #[test]
    fn best_choice_minimizes_frt() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        p.tuple_cost.insert(1, 10.0); // filter1 expensive
        let choices = crate::maestro::enumerate_choices(&w, 2);
        let (idx, frt, bytes) = best_choice(&w, &choices, &p, &[sink]);
        assert!(idx < choices.len());
        assert!(frt.is_finite());
        assert!(bytes > 0.0);
        // Exhaustive check: no other choice strictly better.
        for c in &choices {
            let (f, _) = first_response_time(&w, c, &p, &[sink]);
            assert!(f >= frt - 1e-9);
        }
    }

    #[test]
    fn already_feasible_zero_ancestor_cost() {
        // scan → sink: FRT is just the single-tuple latency.
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        let p = CostParams::new();
        let (frt, bytes) = first_response_time(&w, &[], &p, &[k]);
        assert_eq!(bytes, 0.0);
        assert!(frt <= 3.0, "pipelined FRT should be tiny, got {frt}");
        let _ = s;
    }

    #[test]
    fn assignment_respects_budget_and_favors_heavy_ops() {
        // scan → heavy → sink, one region, budget 8.
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let h = w.add(OpSpec::unary("heavy", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, h, 0);
        w.connect(h, k, 0);
        let mut p = CostParams::new();
        p.source_rows.insert(s, 100_000.0);
        p.tuple_cost.insert(h, 50.0);
        let regions = crate::maestro::region::regions_of(&w);
        let rows = cardinalities(&w, &p);
        let assigned = assign_workers(&w, &regions, &rows, &p, 8, &HashMap::new());
        assert_eq!(assigned.iter().sum::<usize>(), 8, "{assigned:?}");
        assert!(
            assigned[h] > assigned[s] && assigned[h] > assigned[k],
            "heavy op should dominate the budget: {assigned:?}"
        );
        for &n in &assigned {
            assert!(n >= 1);
        }
    }

    #[test]
    fn assignment_caps_at_estimated_rows() {
        // A 3-row workflow must not fan out to 8 workers per op.
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        let mut p = CostParams::new();
        p.source_rows.insert(s, 3.0);
        let regions = crate::maestro::region::regions_of(&w);
        let rows = cardinalities(&w, &p);
        let assigned = assign_workers(&w, &regions, &rows, &p, 16, &HashMap::new());
        assert!(assigned[s] <= 3 && assigned[k] <= 3, "{assigned:?}");
    }

    #[test]
    fn assignment_keeps_fixed_ops_and_one_to_one_groups() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        // Materialize the probe edge → writer (one-to-one behind
        // filter1) + reader appear.
        let plan = plan_for_choice(&w, &[3], &p, &[sink], 10, &HashMap::new());
        let m = apply_choice(&w, &[3]);
        let writer = m.writers[0];
        // Writer count matches its one-to-one producer (filter1 = op 1).
        assert_eq!(plan.workers[writer], plan.workers[1], "{:?}", plan.workers);
        // Fixed pin survives assignment.
        let g = crate::maestro::region_graph::region_graph_ext(&m.workflow, &m.links);
        let rows = cardinalities(&m.workflow, &p);
        let mut fixed = HashMap::new();
        fixed.insert(m.readers[0], 2usize);
        let assigned = assign_workers(&m.workflow, &g.regions, &rows, &p, 10, &fixed);
        assert_eq!(assigned[m.readers[0]], 2);
    }

    /// scan → h1..h4 (heavy) → blocking sink: a pipeline long enough
    /// that splitting it with a materialization lets the per-region
    /// worker budget apply twice.
    fn heavy_chain() -> (Workflow, usize) {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let mut prev = s;
        for name in ["h1", "h2", "h3", "h4"] {
            let h = w.add(OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| {
                Box::new(Noop)
            }));
            w.connect(prev, h, 0);
            prev = h;
        }
        let k = w.add(
            OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
                .with_blocking(vec![0]),
        );
        w.connect(prev, k, 0);
        (w, k)
    }

    #[test]
    fn tight_memory_budget_flips_elastic_choice() {
        let (w, sink) = heavy_chain();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 100_000.0);
        for op in 1..=4 {
            p.tuple_cost.insert(op, 10.0);
        }
        p.mat_byte_cost = 0.001; // cheap disk, plenty of memory…
        // Choice 1 materializes the h2→h3 edge (edge index 2),
        // splitting the heavy chain into two regions that each get the
        // full worker budget.
        let choices = vec![vec![], vec![2usize]];
        let (unbounded, plan) = best_choice_elastic(&w, &choices, &p, &[sink], 8);
        assert_eq!(
            unbounded, 1,
            "with memory to spare the split wins (frt {})",
            plan.estimated_frt
        );
        // …now memory is tight: the store's volume has to spill, and
        // the spill traffic out-costs the parallelism the split buys.
        p.memory_budget_bytes = 1.0;
        p.spill_byte_cost = 1.0;
        let (tight, tight_plan) = best_choice_elastic(&w, &choices, &p, &[sink], 8);
        assert_eq!(
            tight, 0,
            "tight budget prices the mat volume as spill traffic (frt {})",
            tight_plan.estimated_frt
        );
        // The same choice is strictly more expensive under pressure.
        let rich = plan_for_choice(&w, &choices[1], &p, &[sink], 8, &HashMap::new());
        assert!(rich.estimated_frt > plan.estimated_frt);
    }

    #[test]
    fn ample_budget_prices_no_spill() {
        let (w, sink) = heavy_chain();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 100_000.0);
        let base = plan_for_choice(&w, &[2], &p, &[sink], 8, &HashMap::new());
        // A budget bigger than all state in any region changes nothing.
        p.memory_budget_bytes = 1e12;
        let ample = plan_for_choice(&w, &[2], &p, &[sink], 8, &HashMap::new());
        assert_eq!(ample.estimated_frt, base.estimated_frt);
        assert_eq!(ample.workers, base.workers);
    }

    #[test]
    fn calibrate_spill_uses_observed_bandwidth() {
        let mut p = CostParams::new();
        let configured = p.spill_byte_cost;
        // No traffic observed → the configured constant stands.
        p.calibrate_spill(&crate::metrics::SpillStats::default());
        assert_eq!(p.spill_byte_cost, configured);
        // 2000 bytes moved in 4 ms → 2 µs/byte, same unit as the
        // tuple-cost calibration.
        let stats = crate::metrics::SpillStats {
            bytes_spilled: 1000,
            bytes_read_back: 1000,
            spill_write_ns: 2_000_000,
            spill_read_ns: 2_000_000,
            ..Default::default()
        };
        p.calibrate_spill(&stats);
        assert!((p.spill_byte_cost - 2.0).abs() < 1e-12, "{}", p.spill_byte_cost);
    }

    #[test]
    fn elastic_plan_beats_or_matches_static_frt() {
        let (w, sink) = fig_4_1();
        let mut p = CostParams::new();
        p.source_rows.insert(0, 10_000.0);
        p.tuple_cost.insert(1, 10.0);
        let choices = crate::maestro::enumerate_choices(&w, 2);
        let (_, static_frt, _) = best_choice(&w, &choices, &p, &[sink]);
        let (_, plan) = best_choice_elastic(&w, &choices, &p, &[sink], 8);
        // The budget (8 > the 1-worker authored counts) can only help.
        assert!(
            plan.estimated_frt <= static_frt + 1e-9,
            "elastic {} vs static {static_frt}",
            plan.estimated_frt
        );
    }
}

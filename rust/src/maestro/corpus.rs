//! Workflow corpus for the Table 4.1 analysis.
//!
//! The paper surveyed sample/production workflows from Alteryx,
//! RapidMiner, Dataiku and Texera (Figs. 4.16–4.19), counting operators
//! with multiple inputs, blocking links, and whether the naive region
//! graph is cyclic (i.e. materialization is required). This module
//! rebuilds representative workflow *shapes* from those systems so the
//! analysis is reproducible; see `bench_ch4 corpus`.

use crate::engine::dag::{OpSpec, Workflow};
use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::PartitionScheme;
use crate::tuple::Tuple;
use crate::workloads::VecSource;

struct Noop;

impl Operator for Noop {
    fn name(&self) -> &str {
        "noop"
    }
    fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
        out.emit(t);
    }
}

fn src(w: &mut Workflow, name: &str) -> usize {
    w.add(OpSpec::source(name, 1, |_, _| {
        Box::new(VecSource::new(Vec::new()))
    }))
}

fn unary(w: &mut Workflow, name: &str) -> usize {
    w.add(OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| {
        Box::new(Noop)
    }))
}

fn blocking_unary(w: &mut Workflow, name: &str) -> usize {
    w.add(
        OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
            .with_blocking(vec![0]),
    )
}

fn join(w: &mut Workflow, name: &str) -> usize {
    w.add(OpSpec::binary(
        name,
        1,
        [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
        vec![0],
        |_, _| Box::new(Noop),
    ))
}

/// A corpus entry: a named workflow shape.
pub struct CorpusEntry {
    pub system: &'static str,
    pub name: &'static str,
    pub workflow: Workflow,
}

/// Analysis row (the Table 4.1 columns).
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusAnalysis {
    pub system: String,
    pub name: String,
    pub operators: usize,
    pub multi_input_ops: usize,
    pub blocking_links: usize,
    pub regions: usize,
    pub cyclic: bool,
    pub materialization_choices: usize,
}

/// Build the corpus.
pub fn corpus() -> Vec<CorpusEntry> {
    let mut out = Vec::new();

    // Alteryx-style (Fig. 4.16): input → prep chain → self-join on a
    // replicated stream → summarize → output.
    {
        let mut w = Workflow::new();
        let s = src(&mut w, "input");
        let clean = unary(&mut w, "data_cleansing");
        let formula = unary(&mut w, "formula");
        let j = join(&mut w, "join");
        let sum = blocking_unary(&mut w, "summarize");
        let sink = unary(&mut w, "browse");
        w.connect(s, clean, 0);
        w.connect(clean, formula, 0);
        w.connect(clean, j, 0); // build from the same cleansed stream
        w.connect(formula, j, 1); // probe
        w.connect(j, sum, 0);
        w.connect(sum, sink, 0);
        out.push(CorpusEntry { system: "Alteryx", name: "self_join_summarize", workflow: w });
    }

    // RapidMiner-style (Fig. 4.17): two retrieves → preprocess →
    // join → model (blocking) → apply → output.
    {
        let mut w = Workflow::new();
        let s1 = src(&mut w, "retrieve_a");
        let s2 = src(&mut w, "retrieve_b");
        let p1 = unary(&mut w, "select_attrs");
        let p2 = unary(&mut w, "filter_examples");
        let j = join(&mut w, "join");
        let model = blocking_unary(&mut w, "train_model");
        let apply = unary(&mut w, "apply_model");
        let sink = unary(&mut w, "store");
        w.connect(s1, p1, 0);
        w.connect(s2, p2, 0);
        w.connect(p1, j, 0);
        w.connect(p2, j, 1);
        w.connect(j, model, 0);
        w.connect(model, apply, 0);
        w.connect(apply, sink, 0);
        out.push(CorpusEntry { system: "RapidMiner", name: "join_train_apply", workflow: w });
    }

    // Dataiku-style (Fig. 4.18): dataset → split into two prepare
    // recipes → stack (union) → group (blocking) → output; plus a
    // self-join branch.
    {
        let mut w = Workflow::new();
        let s = src(&mut w, "dataset");
        let p1 = unary(&mut w, "prepare_a");
        let p2 = unary(&mut w, "prepare_b");
        let stack = w.add(OpSpec::binary(
            "stack",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![],
            |_, _| Box::new(Noop),
        ));
        let grp = blocking_unary(&mut w, "group");
        let j = join(&mut w, "join_back");
        let sink = unary(&mut w, "output");
        w.connect(s, p1, 0);
        w.connect(s, p2, 0);
        w.connect(p1, stack, 0);
        w.connect(p2, stack, 1);
        w.connect(stack, grp, 0);
        w.connect(grp, j, 0); // build: grouped aggregate
        w.connect(s, j, 1); // probe: original rows → CYCLE via s's region
        w.connect(j, sink, 0);
        out.push(CorpusEntry { system: "Dataiku", name: "group_join_back", workflow: w });
    }

    // Texera-style (Fig. 4.19 / Fig. 4.2): tweets + zipcode history,
    // three joins on zipcode with replicated build input, ML classify,
    // two visualizations.
    {
        let mut w = Workflow::new();
        let hist = src(&mut w, "scan_history");
        let filt = unary(&mut w, "filter_zero_fires");
        let tw_before = src(&mut w, "tweets_before");
        let tw_during = src(&mut w, "tweets_during");
        let kw = unary(&mut w, "keyword_fire");
        let j1 = join(&mut w, "join_before");
        let j2 = join(&mut w, "join_during");
        let ml1 = unary(&mut w, "ml_before");
        let ml2 = unary(&mut w, "ml_during");
        let bar = unary(&mut w, "bar_chart");
        let scatter = unary(&mut w, "scatterplot");
        w.connect(hist, filt, 0);
        w.connect(filt, j1, 0); // build 1
        w.connect(filt, j2, 0); // build 2 (replicated build input)
        w.connect(tw_before, j1, 1);
        w.connect(tw_during, kw, 0);
        w.connect(kw, j2, 1);
        w.connect(tw_during, scatter, 0);
        w.connect(j1, ml1, 0);
        w.connect(j2, ml2, 0);
        w.connect(ml1, bar, 0);
        w.connect(ml2, bar, 0);
        out.push(CorpusEntry { system: "Texera", name: "climate_wildfire", workflow: w });
    }

    out
}

/// Analyze every corpus workflow (the Table 4.1 rows).
pub fn analyze() -> Vec<CorpusAnalysis> {
    corpus()
        .into_iter()
        .map(|e| {
            let w = &e.workflow;
            let g = crate::maestro::region_graph::region_graph(w);
            let cyclic = !g.is_acyclic();
            let choices = crate::maestro::enumerate::enumerate_choices(w, 2);
            CorpusAnalysis {
                system: e.system.to_string(),
                name: e.name.to_string(),
                operators: w.ops.len(),
                multi_input_ops: (0..w.ops.len())
                    .filter(|&i| w.ops[i].input_partitioning.len() > 1)
                    .count(),
                blocking_links: w
                    .edges
                    .iter()
                    .filter(|e| w.is_blocking_edge(e))
                    .count(),
                regions: g.regions.len(),
                cyclic,
                materialization_choices: if cyclic { choices.len() } else { 0 },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_all_four_systems() {
        let systems: Vec<&str> = corpus().iter().map(|e| e.system).collect();
        for s in ["Alteryx", "RapidMiner", "Dataiku", "Texera"] {
            assert!(systems.contains(&s), "missing {s}");
        }
    }

    #[test]
    fn all_corpus_workflows_valid() {
        for e in corpus() {
            assert!(e.workflow.validate().is_ok(), "{} invalid", e.name);
        }
    }

    #[test]
    fn analysis_finds_cyclic_and_acyclic_cases() {
        let rows = analyze();
        assert!(rows.iter().any(|r| r.cyclic), "no cyclic example");
        assert!(rows.iter().any(|r| !r.cyclic), "no acyclic example");
        // Cyclic workflows must have at least one repair choice.
        for r in rows.iter().filter(|r| r.cyclic) {
            assert!(r.materialization_choices > 0, "{} unrepairable", r.name);
        }
    }

    #[test]
    fn blocking_links_counted() {
        let rows = analyze();
        for r in &rows {
            assert!(r.blocking_links >= 1, "{}: no blocking links", r.name);
            assert!(r.regions >= 2);
        }
    }
}

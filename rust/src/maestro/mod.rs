//! **Maestro** (Ch. 4): result-aware, elastic region scheduling for
//! pipelined execution.
//!
//! Planning pipeline: [`region`] splits the workflow DAG at blocking
//! links into regions; [`region_graph`](mod@region_graph) derives
//! inter-region dependencies; [`cycles`] detects infeasible (cyclic)
//! region graphs
//! and repairs them by inserting **materialization** on pipelined links;
//! [`enumerate`] lists every minimal materialization choice (§4.5.1);
//! [`cost`] scores each choice by **first response time** (§4.5.3) — and,
//! when a worker budget is configured
//! ([`Config::max_workers`](crate::config::Config::max_workers)), jointly
//! assigns per-region worker counts to each choice
//! ([`cost::best_choice_elastic`]); [`corpus`] bundles the workflow
//! shapes of Table 4.1.
//!
//! Execution: [`scheduler`] runs the chosen plan region-by-region on
//! the engine — deploy all workers with dormant sources, activate each
//! region's sources in topological region order, and between
//! activations **observe** the completed regions (actual cardinalities,
//! materialized bytes) and **re-plan** the remaining
//! regions' worker counts, applying changes through the engine's
//! fenced [`scale`](crate::engine::scale) protocol while those workers
//! are still dormant. Every estimate, observation and scale decision is
//! recorded in the [`ScheduleOutcome`] trail, so a run's adaptive
//! behavior is inspectable after the fact.
//!
//! See `docs/ARCHITECTURE.md` at the repository root for the full
//! region-scheduling lifecycle walkthrough.

pub mod region;
pub mod region_graph;
pub mod cycles;
pub mod enumerate;
pub mod cost;
pub mod materialize;
pub mod scheduler;
pub mod corpus;

pub use cost::{
    best_choice_elastic, first_response_time, AllocGroup, CostParams, ElasticPlan,
};
pub use enumerate::enumerate_choices;
pub use region::{regions_of, Region};
pub use region_graph::{region_graph, RegionGraph};
pub use scheduler::{
    MaestroScheduler, ObservedOp, RegionPlan, ScaleDecision, ScheduleOutcome,
};

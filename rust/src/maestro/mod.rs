//! **Maestro** (Ch. 4): result-aware region scheduling for pipelined
//! execution.
//!
//! Pipeline: [`region`] splits the workflow DAG at blocking links into
//! regions; [`region_graph`] derives inter-region dependencies;
//! [`cycles`] detects infeasible (cyclic) region graphs and repairs
//! them by inserting **materialization** on pipelined links;
//! [`enumerate`] lists every minimal materialization choice (§4.5.1);
//! [`cost`] scores each choice by **first response time** (§4.5.3);
//! [`scheduler`] executes the chosen plan region-by-region on the
//! engine (sources deployed dormant, activated in topological region
//! order); [`corpus`] bundles the workflow shapes of Table 4.1.

pub mod region;
pub mod region_graph;
pub mod cycles;
pub mod enumerate;
pub mod cost;
pub mod materialize;
pub mod scheduler;
pub mod corpus;

pub use cost::{CostParams, first_response_time};
pub use enumerate::enumerate_choices;
pub use region::{regions_of, Region};
pub use region_graph::{region_graph, RegionGraph};
pub use scheduler::{MaestroScheduler, ScheduleOutcome};

//! Cycle detection & feasibility (§4.4.2–4.4.3).
//!
//! A workflow is *schedulable* iff its region graph is acyclic. When it
//! is not (Fig. 4.8), some pipelined link must be materialized to
//! split a region; [`is_feasible`] and [`feasible_with`] are the
//! predicates the enumeration (§4.5.1) searches with.

use crate::engine::dag::Workflow;
use crate::maestro::materialize::apply_choice;
use crate::maestro::region_graph::{region_graph, region_graph_ext};

/// Whether the workflow has a feasible region schedule as-is.
pub fn is_feasible(w: &Workflow) -> bool {
    region_graph(w).is_acyclic()
}

/// Whether materializing the given pipelined edges makes it feasible.
/// The materialized writer→reader couples count as region dependencies
/// (the reader can only consume a *finished* store).
pub fn feasible_with(w: &Workflow, choice: &[usize]) -> bool {
    // Materializing a blocking edge is pointless; reject early.
    for &ei in choice {
        if w.is_blocking_edge(&w.edges[ei]) {
            return false;
        }
    }
    let m = apply_choice(w, choice);
    region_graph_ext(&m.workflow, &m.links).is_acyclic()
}

/// Pipelined edges that are candidates for materialization: those in a
/// region that participates in a region-graph cycle.
pub fn candidate_edges(w: &Workflow) -> Vec<usize> {
    let g = region_graph(w);
    if g.is_acyclic() {
        return Vec::new();
    }
    // A region is "cyclic" if removing it (and incident deps) is needed
    // for topological order — approximate: regions on some self-loop or
    // in a strongly-connected dep component. With self-loops dominating
    // in practice (Fig. 4.1), collect regions with u==v deps plus any
    // region in a dep cycle found by DFS.
    let mut cyclic_regions: Vec<usize> = g
        .deps
        .iter()
        .filter(|(u, v, _)| u == v)
        .map(|(u, _, _)| *u)
        .collect();
    // General cycles: DFS color marking over region deps.
    let n = g.regions.len();
    let mut color = vec![0u8; n]; // 0 white, 1 gray, 2 black
    let mut stack_path: Vec<usize> = Vec::new();
    fn dfs(
        r: usize,
        g: &crate::maestro::region_graph::RegionGraph,
        color: &mut Vec<u8>,
        path: &mut Vec<usize>,
        cyclic: &mut Vec<usize>,
    ) {
        color[r] = 1;
        path.push(r);
        for (u, v, _) in &g.deps {
            if *u == r && u != v {
                if color[*v] == 1 {
                    // Found a cycle: everything from v on the path.
                    let start = path.iter().position(|x| x == v).unwrap();
                    for &x in &path[start..] {
                        if !cyclic.contains(&x) {
                            cyclic.push(x);
                        }
                    }
                } else if color[*v] == 0 {
                    dfs(*v, g, color, path, cyclic);
                }
            }
        }
        path.pop();
        color[r] = 2;
    }
    for r in 0..n {
        if color[r] == 0 {
            dfs(r, &g, &mut color, &mut stack_path, &mut cyclic_regions);
        }
    }
    // Candidate edges: pipelined edges inside cyclic regions.
    let mut out = Vec::new();
    for rid in cyclic_regions {
        out.extend(g.regions[rid].edges.iter().copied());
    }
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    /// Fig. 4.1: replicated scan feeding both join inputs.
    fn fig_4_1() -> Workflow {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f1 = w.add(OpSpec::unary("filter1", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let f2 = w.add(OpSpec::unary("filter2", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        let j = w.add(OpSpec::binary(
            "join",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f1, 0);
        w.connect(s, f2, 0);
        w.connect(f2, j, 0); // build
        w.connect(f1, j, 1); // probe
        w.connect(j, k, 0);
        w
    }

    #[test]
    fn fig_4_1_is_infeasible() {
        assert!(!is_feasible(&fig_4_1()));
    }

    #[test]
    fn materializing_probe_path_makes_feasible() {
        let w = fig_4_1();
        // filter1 feeds the probe input (e3); materializing anywhere on
        // the probe path (e0: scan→filter1, or e3: filter1→probe)
        // defers the probe feed until the build region has completed.
        assert!(feasible_with(&w, &[0]));
        assert!(feasible_with(&w, &[3]));
    }

    #[test]
    fn materializing_build_path_stays_cyclic() {
        let w = fig_4_1();
        // Materializing e1 (scan→filter2, the BUILD path) does not
        // help: the join still sits in the scan/probe region, which
        // both feeds the reader (writer link) and needs the build
        // (blocking link) — a two-region cycle.
        assert!(!feasible_with(&w, &[1]));
    }

    #[test]
    fn materializing_blocking_edge_rejected() {
        let w = fig_4_1();
        // Edge 2 is filter2→join build (already blocking).
        assert!(!feasible_with(&w, &[2]));
    }

    #[test]
    fn candidates_cover_the_cyclic_region() {
        let w = fig_4_1();
        let cands = candidate_edges(&w);
        // The cyclic region contains the pipelined edges 0, 1, 3, 4.
        assert!(cands.contains(&0));
        assert!(cands.contains(&1));
        assert!(!cands.contains(&2), "blocking edge is not a candidate");
    }

    #[test]
    fn acyclic_workflow_has_no_candidates() {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let k = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, k, 0);
        assert!(is_feasible(&w));
        assert!(candidate_edges(&w).is_empty());
    }
}

//! The Maestro scheduler (§4.3): execute a workflow region-by-region.
//!
//! Steps: enumerate materialization choices (if the region graph is
//! cyclic), pick the choice with the least estimated first response
//! time (§4.5.4), rewrite the workflow, deploy with **dormant
//! sources**, then activate each region's sources in topological
//! region order, awaiting completion of its ancestor regions first.
//! Workers of downstream regions are alive from the start (Fig. 4.3:
//! every join worker runs both build and probe phases), so a region's
//! output streams directly into the next region's waiting operators.

use crate::config::Config;
use crate::engine::controller::{ExecSummary, Execution};
use crate::engine::dag::Workflow;
use crate::maestro::cost::{best_choice, CostParams};
use crate::maestro::enumerate::enumerate_choices;
use crate::maestro::materialize::{apply_choice, MatStore};

/// Outcome of a scheduled run.
pub struct ScheduleOutcome {
    pub summary: ExecSummary,
    /// Chosen materialization (edge indices of the original workflow).
    pub choice: Vec<usize>,
    /// Estimated FRT of the chosen plan (cost-model units).
    pub estimated_frt: f64,
    /// Measured first-response time: seconds until a sink operator
    /// emitted… for sinks (no out-edges) we use the sink's own
    /// processing start; recorded as the first tuple *arriving* at the
    /// sink op (`first_output` of its upstream) plus sink latency —
    /// reported here as seconds until any `sink_ops` member saw input.
    pub measured_frt: f64,
    /// Bytes materialized per choice edge.
    pub mat_bytes: Vec<u64>,
    /// Region execution order.
    pub region_order: Vec<usize>,
}

/// Maestro: plans and runs one workflow.
pub struct MaestroScheduler {
    pub config: Config,
    pub cost: CostParams,
    /// Maximum edges per materialization choice considered.
    pub max_mat_edges: usize,
}

impl MaestroScheduler {
    pub fn new(config: Config, cost: CostParams) -> MaestroScheduler {
        MaestroScheduler { config, cost, max_mat_edges: 3 }
    }

    /// Plan only: (chosen edge set, estimated FRT).
    pub fn plan(&self, w: &Workflow, sink_ops: &[usize]) -> (Vec<usize>, f64) {
        let choices = enumerate_choices(w, self.max_mat_edges);
        assert!(
            !choices.is_empty(),
            "no feasible materialization choice (≤{} edges)",
            self.max_mat_edges
        );
        let (idx, frt, _) = best_choice(w, &choices, &self.cost, sink_ops);
        (choices[idx].clone(), frt)
    }

    /// Plan + execute; `sink_ops` are result operators (indices in the
    /// *original* workflow — sinks are preserved by materialization
    /// rewriting).
    pub fn run(&self, w: Workflow, sink_ops: &[usize]) -> ScheduleOutcome {
        let (choice, estimated_frt) = self.plan(&w, sink_ops);
        self.run_with_choice(w, sink_ops, &choice, estimated_frt)
    }

    /// Execute with an explicit materialization choice (experiment
    /// harnesses sweep all choices this way).
    pub fn run_with_choice(
        &self,
        w: Workflow,
        sink_ops: &[usize],
        choice: &[usize],
        estimated_frt: f64,
    ) -> ScheduleOutcome {
        self.run_pluggable(w, sink_ops, choice, estimated_frt, None)
    }

    /// Like [`run_with_choice`](Self::run_with_choice) with an optional
    /// coordinator plugin (e.g. Reshape protecting an operator while
    /// Maestro schedules the regions — the full Texera stack).
    pub fn run_pluggable(
        &self,
        w: Workflow,
        sink_ops: &[usize],
        choice: &[usize],
        estimated_frt: f64,
        plugin: Option<Box<dyn crate::engine::controller::CoordPlugin>>,
    ) -> ScheduleOutcome {
        let m = apply_choice(&w, choice);
        let stores: Vec<MatStore> = m.stores.clone();
        let g = crate::maestro::region_graph::region_graph_ext(&m.workflow, &m.links);
        let order = g
            .topo_order()
            .expect("chosen materialization must yield an acyclic region graph");
        let exec = match plugin {
            Some(p) => Execution::start_scheduled_with_plugin(
                m.workflow.clone(),
                self.config.clone(),
                p,
            ),
            None => Execution::start_scheduled(m.workflow.clone(), self.config.clone()),
        };
        let started = std::time::Instant::now();
        for &rid in &order {
            // Wait for all ancestor regions to fully complete.
            let ancestors = g.ancestors(rid);
            for a in ancestors {
                exec.await_ops(g.regions[a].ops.clone());
            }
            // Activate this region's sources (scans + mat readers).
            let sources: Vec<usize> = g.regions[rid]
                .ops
                .iter()
                .copied()
                .filter(|&op| m.workflow.ops[op].is_source)
                .collect();
            if !sources.is_empty() {
                exec.start_sources(sources);
            }
        }
        let summary = exec.join();
        let _ = started;
        // Measured FRT: first output of any op feeding a sink (the
        // sink's first input) — sinks have no outputs of their own.
        let mut measured = f64::INFINITY;
        for &sink in sink_ops {
            for e in m.workflow.in_edges(sink) {
                if let Some(&t) = summary.first_output.get(&e.from) {
                    measured = measured.min(t);
                }
            }
        }
        ScheduleOutcome {
            summary,
            choice: choice.to_vec(),
            estimated_frt,
            measured_frt: measured,
            mat_bytes: stores.iter().map(|s| s.bytes()).collect(),
            region_order: order,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::partitioner::PartitionScheme;
    use crate::operators::basic::{Cmp, Filter};
    use crate::operators::{CollectSink, HashJoin, SinkHandle};
    use crate::tuple::{Tuple, Value};
    use crate::workloads::VecSource;

    /// Fig. 4.1 with real operators: scan replicates to two filters
    /// feeding build and probe of a strict join.
    fn fig_4_1_real(rows: usize) -> (Workflow, SinkHandle, usize) {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let data: Vec<Tuple> = (0..rows)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int((i % 50) as i64), Value::Int(i as i64)]))
                .collect();
            Box::new(VecSource::new(data))
        }));
        // filter1 (probe path): keep ~80%.
        let f1 = w.add(OpSpec::unary("filter1", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
        }));
        // filter2 (build path): keep one row per key (val < 50).
        let f2 = w.add(OpSpec::unary("filter2", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(1, Cmp::Lt, Value::Int(50)))
        }));
        // Strict join: errors if probe precedes build EOF — exactly the
        // situation Maestro must prevent.
        let j = w.add(OpSpec::binary(
            "join",
            2,
            [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
            vec![0],
            |_, _| Box::new(HashJoin::new(0, 0).strict()),
        ));
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(scan, f1, 0);
        w.connect(scan, f2, 0);
        w.connect(f2, j, 0);
        w.connect(f1, j, 1);
        w.connect(j, sink, 0);
        (w, handle, sink)
    }

    #[test]
    fn schedules_infeasible_workflow_correctly() {
        let rows = 5_000;
        let (w, handle, sink) = fig_4_1_real(rows);
        let mut cost = CostParams::new();
        cost.source_rows.insert(0, rows as f64);
        cost.selectivity.insert(2, 50.0 / rows as f64); // filter2 tiny
        let sched = MaestroScheduler::new(Config::for_tests(), cost);
        let outcome = sched.run(w, &[sink]);
        // The strict join never saw an early probe tuple, and results
        // are complete: every scanned row joins its key row.
        assert_eq!(handle.total(), rows as u64, "join results incomplete");
        assert!(!outcome.choice.is_empty(), "materialization was required");
        assert!(outcome.mat_bytes.iter().sum::<u64>() > 0);
        assert!(outcome.region_order.len() >= 2);
        assert!(outcome.measured_frt.is_finite());
    }

    #[test]
    fn plan_picks_minimal_frt_choice() {
        let (w, _handle, sink) = fig_4_1_real(1000);
        let mut cost = CostParams::new();
        cost.source_rows.insert(0, 1000.0);
        cost.selectivity.insert(2, 0.05);
        let sched = MaestroScheduler::new(Config::for_tests(), cost.clone());
        let (choice, frt) = sched.plan(&w, &[sink]);
        // Verify optimality among enumerated choices.
        let choices = enumerate_choices(&w, 3);
        for c in &choices {
            let (f, _) = crate::maestro::cost::first_response_time(&w, c, &cost, &[sink]);
            assert!(f >= frt - 1e-9, "plan missed better choice {c:?}");
        }
        assert!(choices.contains(&choice));
    }

    #[test]
    fn feasible_workflow_runs_without_materialization() {
        // Separate build/probe scans: no cycle, empty choice.
        let mut w = Workflow::new();
        let b = w.add(OpSpec::source("build", 1, |_, _| {
            Box::new(VecSource::new(
                (0..10).map(|k| Tuple::new(vec![Value::Int(k)])).collect(),
            ))
        }));
        let p = w.add(OpSpec::source("probe", 1, |_, _| {
            Box::new(VecSource::new(
                (0..100).map(|i| Tuple::new(vec![Value::Int(i % 10)])).collect(),
            ))
        }));
        let j = w.add(OpSpec::binary(
            "join",
            2,
            [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
            vec![0],
            |_, _| Box::new(HashJoin::new(0, 0).strict()),
        ));
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(b, j, 0);
        w.connect(p, j, 1);
        w.connect(j, sink, 0);
        let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
        let outcome = sched.run(w, &[sink]);
        assert!(outcome.choice.is_empty());
        assert_eq!(handle.total(), 100);
        assert_eq!(outcome.mat_bytes.len(), 0);
    }
}

//! The Maestro scheduler (§4.3): execute a workflow region-by-region,
//! **adaptively**.
//!
//! The static flow: enumerate materialization choices (if the region
//! graph is cyclic), pick the choice with the least estimated first
//! response time (§4.5.4), rewrite the workflow, deploy with **dormant
//! sources**, then activate each region's sources in topological region
//! order, awaiting completion of its ancestor regions first. Workers of
//! downstream regions are alive from the start (Fig. 4.3: every join
//! worker runs both build and probe phases), so a region's output
//! streams directly into the next region's waiting operators.
//!
//! With a worker budget ([`Config::max_workers`] > 0) the scheduler is
//! additionally **elastic and observation-driven**:
//!
//! 1. **Plan** — [`best_choice_elastic`] jointly picks the
//!    materialization choice *and* a per-region worker-count assignment
//!    under the budget; the workflow deploys at the assigned counts.
//! 2. **Observe** — whenever an ancestor region completes, the
//!    scheduler reads the execution's per-worker statistics (exact
//!    produced counts and busy time) and every finished [`MatStore`]'s
//!    row count and tuple width, and pins them into the cost model
//!    ([`CostParams::pinned_rows`]) — actual cardinalities replace
//!    plan-time guesses. Observed busy time is folded into per-operator
//!    cost calibration (`busy_ns / processed`, in µs/tuple, into
//!    [`CostParams::tuple_cost`]), so later regions are priced from
//!    measured per-tuple cost instead of the configured default.
//! 3. **Re-plan** — the remaining (not-yet-activated) regions' worker
//!    counts are re-assigned under the same budget with the corrected
//!    model. Deltas are applied through
//!    [`Execution::scale_operator`] (one fenced epoch per operator)
//!    while those regions' workers are still alive-but-dormant, i.e.
//!    before [`Execution::start_sources`] wakes the region. **Every**
//!    operator class is eligible — sources (splittable scan ranges,
//!    incl. mat readers), scatter-merge and broadcast-input operators
//!    scale through the universal fence (engine::scale); only an
//!    operator whose scale request the engine actually refuses (e.g.
//!    its region drained early and workers completed) is pinned at its
//!    current count and never retried.
//!    With a grace window set ([`MaestroScheduler::mid_replan_after_ms`])
//!    the re-planner additionally runs **mid-region**: a region still
//!    executing past the window is re-planned from its *live*
//!    probe-stream observations and the deltas are applied to the
//!    active region as one fenced migration
//!    ([`PlanDelta::Replan`](crate::engine::migrate::PlanDelta) via
//!    [`Execution::migrate`]), so a refusal rolls the whole batch back.
//! 4. **Record** — every step lands in the [`ScheduleOutcome`] decision
//!    trail ([`RegionPlan`]): estimated vs observed cardinalities with
//!    q-errors, the worker assignment after each re-plan, each scale
//!    decision with its fence duration, and per-region completion times
//!    (the FRT contribution of each ancestor region).
//!
//! [`Config::max_workers`]: crate::config::Config::max_workers
//! [`CostParams::pinned_rows`]: crate::maestro::cost::CostParams
//! [`MatStore`]: crate::maestro::materialize::MatStore
//! [`best_choice_elastic`]: crate::maestro::cost::best_choice_elastic

use crate::config::Config;
use crate::engine::controller::{ExecSummary, Execution};
use crate::engine::dag::Workflow;
use crate::maestro::cost::{
    best_choice, best_choice_elastic, cardinalities, plan_for_choice, CostParams, ElasticPlan,
};
use crate::maestro::enumerate::enumerate_choices;
use crate::maestro::materialize::{apply_choice, MatStore, Materialized};
use crate::maestro::region_graph::RegionGraph;
use crate::metrics::q_error;
use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

/// One estimate-vs-observation row of the decision trail.
#[derive(Clone, Debug)]
pub struct ObservedOp {
    /// Operator index in the materialized workflow.
    pub op: usize,
    /// Rows-out the initial plan estimated for it.
    pub estimated_rows: f64,
    /// Rows-out actually observed when its region completed.
    pub observed_rows: f64,
    /// `max(est/obs, obs/est)` — see [`q_error`].
    pub q_error: f64,
    /// Measured per-tuple cost in µs (`busy_ns / processed / 1000`)
    /// folded into the cost model, when the operator processed anything.
    pub tuple_cost_us: Option<f64>,
}

/// One elastic-scaling decision taken by a re-plan.
#[derive(Clone, Debug)]
pub struct ScaleDecision {
    /// Operator index in the materialized workflow.
    pub op: usize,
    pub from: usize,
    pub to: usize,
    /// Fence duration in milliseconds (0 when the engine refused).
    pub fence_ms: f64,
    /// Whether the engine accepted the scale (a refusal leaves the
    /// operator at `from`).
    pub applied: bool,
}

/// Decision-trail entry recorded before each region activation that
/// had observations to act on.
#[derive(Clone, Debug)]
pub struct RegionPlan {
    /// Region about to be activated.
    pub region: usize,
    /// Seconds since deployment when this re-plan ran.
    pub at: f64,
    /// Operators newly pinned to observed cardinalities by this
    /// re-plan.
    pub observed: Vec<ObservedOp>,
    /// Worker count per materialized operator after this re-plan.
    pub workers: Vec<usize>,
    /// Scale requests issued (empty when the revised assignment matched
    /// the current one).
    pub decisions: Vec<ScaleDecision>,
    /// `true` when this re-plan ran *inside* a region — driven by the
    /// live probe stream instead of ancestor completion — and its
    /// deltas were applied as one fenced migration
    /// ([`PlanDelta::Replan`](crate::engine::migrate::PlanDelta)).
    pub mid_region: bool,
    /// The migration's per-step decision trail (step descriptions, in
    /// apply order, rollback steps included). Empty for pre-activation
    /// re-plans, which scale one operator at a time.
    pub migration_steps: Vec<String>,
}

/// Outcome of a scheduled run.
#[derive(Debug)]
pub struct ScheduleOutcome {
    pub summary: ExecSummary,
    /// Chosen materialization (edge indices of the original workflow).
    pub choice: Vec<usize>,
    /// Estimated FRT of the chosen plan (cost-model units).
    pub estimated_frt: f64,
    /// Measured first-response time: seconds from deployment until a
    /// `sink_ops` member delivered its **first result** (the sink's own
    /// first-output timestamp — sinks report result delivery as
    /// output). For a sink operator that never reports output (a custom
    /// sink that swallows tuples), this falls back to the first output
    /// of the operators feeding it, i.e. input arrival.
    pub measured_frt: f64,
    /// Bytes materialized per choice edge.
    pub mat_bytes: Vec<u64>,
    /// Region execution order.
    pub region_order: Vec<usize>,
    /// Worker count per materialized operator at deployment.
    pub initial_workers: Vec<usize>,
    /// Worker count per materialized operator after the last re-plan.
    pub final_workers: Vec<usize>,
    /// Decision trail: one entry per region activation that re-planned.
    pub replans: Vec<RegionPlan>,
    /// `(region, seconds since deployment)` when each awaited region's
    /// completion was observed — the per-region contribution to the
    /// measured FRT of everything scheduled after it.
    pub region_completed_at: Vec<(usize, f64)>,
}

/// Maestro: plans and runs one workflow.
pub struct MaestroScheduler {
    pub config: Config,
    pub cost: CostParams,
    /// Maximum edges per materialization choice considered.
    pub max_mat_edges: usize,
    /// Mid-region re-plan grace window in milliseconds (0 = off). When
    /// set (and a worker budget is active), a region still running
    /// this long after activation is re-planned **mid-region** from
    /// its live probe-stream observations, the deltas applied as one
    /// fenced migration — at most once per region.
    pub mid_replan_after_ms: u64,
    /// Budget override: when set, elastic planning uses this many
    /// workers instead of `config.max_workers`. The serving layer sets
    /// it to a job's arbitrated *share* of the global budget, so a
    /// scheduler running inside the multi-tenant service plans against
    /// its grant, not the whole cluster.
    pub budget_override: Option<usize>,
}

impl MaestroScheduler {
    pub fn new(config: Config, mut cost: CostParams) -> MaestroScheduler {
        // The engine budget is authoritative: caller-built CostParams
        // that didn't set one inherit `config.memory_budget_bytes`, so
        // spill pricing is active exactly when spilling is possible.
        if cost.memory_budget_bytes == 0.0 {
            cost.memory_budget_bytes = config.memory_budget_bytes as f64;
        }
        MaestroScheduler {
            config,
            cost,
            max_mat_edges: 3,
            mid_replan_after_ms: 0,
            budget_override: None,
        }
    }

    /// Plan under `workers` instead of `config.max_workers`.
    pub fn with_budget(mut self, workers: usize) -> MaestroScheduler {
        self.budget_override = Some(workers);
        self
    }

    /// The per-region worker budget (0 = elasticity off, deploy at
    /// authored counts).
    fn budget(&self) -> usize {
        self.budget_override.unwrap_or(self.config.max_workers)
    }

    /// Plan only, at authored worker counts: (chosen edge set,
    /// estimated FRT).
    pub fn plan(&self, w: &Workflow, sink_ops: &[usize]) -> (Vec<usize>, f64) {
        let choices = enumerate_choices(w, self.max_mat_edges);
        assert!(
            !choices.is_empty(),
            "no feasible materialization choice (≤{} edges)",
            self.max_mat_edges
        );
        let (idx, frt, _) = best_choice(w, &choices, &self.cost, sink_ops);
        (choices[idx].clone(), frt)
    }

    /// Joint plan under the worker budget: materialization choice plus
    /// per-region worker assignment (requires `config.max_workers > 0`).
    pub fn plan_elastic(&self, w: &Workflow, sink_ops: &[usize]) -> ElasticPlan {
        assert!(self.budget() > 0, "plan_elastic needs config.max_workers > 0");
        let choices = enumerate_choices(w, self.max_mat_edges);
        assert!(
            !choices.is_empty(),
            "no feasible materialization choice (≤{} edges)",
            self.max_mat_edges
        );
        let (_, plan) =
            best_choice_elastic(w, &choices, &self.cost, sink_ops, self.budget());
        plan
    }

    /// Plan + execute; `sink_ops` are result operators (indices in the
    /// *original* workflow — sinks are preserved by materialization
    /// rewriting).
    pub fn run(&self, w: Workflow, sink_ops: &[usize]) -> ScheduleOutcome {
        if self.budget() > 0 {
            // Hand the joint plan straight to execution — recomputing it
            // in run_inner would be duplicate work and a silent-
            // divergence hazard between two "identical" plan calls.
            let plan = self.plan_elastic(&w, sink_ops);
            let choice = plan.choice.clone();
            let frt = plan.estimated_frt;
            self.run_inner(w, sink_ops, &choice, frt, Some(plan), None)
        } else {
            let (choice, estimated_frt) = self.plan(&w, sink_ops);
            self.run_with_choice(w, sink_ops, &choice, estimated_frt)
        }
    }

    /// Execute with an explicit materialization choice (experiment
    /// harnesses sweep all choices this way). Under a worker budget the
    /// assignment for the given choice is recomputed deterministically.
    pub fn run_with_choice(
        &self,
        w: Workflow,
        sink_ops: &[usize],
        choice: &[usize],
        estimated_frt: f64,
    ) -> ScheduleOutcome {
        self.run_pluggable(w, sink_ops, choice, estimated_frt, None)
    }

    /// Like [`run_with_choice`](Self::run_with_choice) with an optional
    /// coordinator plugin (e.g. Reshape protecting an operator while
    /// Maestro schedules the regions — the full Texera stack).
    pub fn run_pluggable(
        &self,
        w: Workflow,
        sink_ops: &[usize],
        choice: &[usize],
        estimated_frt: f64,
        plugin: Option<Box<dyn crate::engine::controller::CoordPlugin>>,
    ) -> ScheduleOutcome {
        self.run_inner(w, sink_ops, choice, estimated_frt, None, plugin)
    }

    /// The execution loop behind `run`/`run_with_choice`/
    /// `run_pluggable`. `plan` carries a precomputed elastic plan (from
    /// [`run`](Self::run)); when absent and a budget is set, the plan
    /// for `choice` is recomputed deterministically.
    fn run_inner(
        &self,
        w: Workflow,
        sink_ops: &[usize],
        choice: &[usize],
        mut estimated_frt: f64,
        plan: Option<ElasticPlan>,
        plugin: Option<Box<dyn crate::engine::controller::CoordPlugin>>,
    ) -> ScheduleOutcome {
        let mut m = apply_choice(&w, choice);
        let stores: Vec<MatStore> = m.stores.clone();
        let g = crate::maestro::region_graph::region_graph_ext(&m.workflow, &m.links);
        let order = g
            .topo_order()
            .expect("chosen materialization must yield an acyclic region graph");
        // Initial estimates (for the decision trail's q-errors) and, if
        // a budget is set, the deploy-time worker assignment.
        let mut cost = self.cost.clone();
        let mut initial_rows = cardinalities(&m.workflow, &cost);
        if self.budget() > 0 {
            let plan = plan.unwrap_or_else(|| {
                plan_for_choice(&w, choice, &cost, sink_ops, self.budget(), &HashMap::new())
            });
            for (op, &n) in plan.workers.iter().enumerate() {
                m.workflow.ops[op].workers = n;
            }
            // Report the estimate that matches the deployed counts — a
            // caller sweeping choices passes the authored-counts FRT,
            // which would be inconsistent with what actually runs.
            estimated_frt = plan.estimated_frt;
            // The plan's estimates include mat-reader seeding — use them
            // as the q-error baseline in the decision trail.
            initial_rows = plan.est_rows;
        }
        let mut current: Vec<usize> = m.workflow.ops.iter().map(|o| o.workers).collect();
        let initial_workers = current.clone();

        let exec = match plugin {
            Some(p) => Execution::start_scheduled_with_plugin(
                m.workflow.clone(),
                self.config.clone(),
                p,
            ),
            None => Execution::start_scheduled(m.workflow.clone(), self.config.clone()),
        };
        let started = Instant::now();
        let mut completed_regions: HashSet<usize> = HashSet::new();
        let mut region_completed_at: Vec<(usize, f64)> = Vec::new();
        let mut pinned_ops: HashSet<usize> = HashSet::new();
        let mut unscalable: HashSet<usize> = HashSet::new();
        let mut replans: Vec<RegionPlan> = Vec::new();
        for (pos, &rid) in order.iter().enumerate() {
            // Wait for all ancestor regions to fully complete.
            for a in g.ancestors(rid) {
                exec.await_ops(g.regions[a].ops.clone());
                if completed_regions.insert(a) {
                    region_completed_at.push((a, started.elapsed().as_secs_f64()));
                }
            }
            // Observe + re-plan the not-yet-activated regions before
            // waking this one.
            if self.budget() > 0 && !completed_regions.is_empty() {
                let plan = self.replan_remaining(
                    &exec,
                    &m,
                    &g,
                    &order[pos..],
                    rid,
                    &completed_regions,
                    &stores,
                    &initial_rows,
                    &mut cost,
                    &mut current,
                    &mut pinned_ops,
                    &mut unscalable,
                    started,
                );
                replans.push(plan);
            }
            // Activate this region's sources (scans + mat readers).
            let sources: Vec<usize> = g.regions[rid]
                .ops
                .iter()
                .copied()
                .filter(|&op| m.workflow.ops[op].is_source)
                .collect();
            if !sources.is_empty() {
                exec.start_sources(sources);
            }
            // Mid-region re-plan (opt-in): if the region is still
            // running once the grace window passes, correct its worker
            // assignment from the live probe stream — at most one
            // mid-region migration per region.
            if self.budget() > 0
                && self.mid_replan_after_ms > 0
                && !exec.await_ops_timeout(
                    g.regions[rid].ops.clone(),
                    Duration::from_millis(self.mid_replan_after_ms),
                )
            {
                if let Some(plan) = self.mid_region_replan(
                    &exec,
                    &m,
                    &g,
                    &order[pos..],
                    rid,
                    &initial_rows,
                    &cost,
                    &mut current,
                    &mut unscalable,
                    started,
                ) {
                    replans.push(plan);
                }
            }
        }
        let summary = exec.join();
        // Measured FRT: the first *output* of a sink operator itself —
        // sinks report result delivery through the emitter. Custom
        // sinks that never emit fall back to input arrival (first
        // output of the operators feeding them).
        let mut measured = f64::INFINITY;
        for &sink in sink_ops {
            if let Some(&t) = summary.first_output.get(&sink) {
                measured = measured.min(t);
            } else {
                for e in m.workflow.in_edges(sink) {
                    if let Some(&t) = summary.first_output.get(&e.from) {
                        measured = measured.min(t);
                    }
                }
            }
        }
        ScheduleOutcome {
            summary,
            choice: choice.to_vec(),
            estimated_frt,
            measured_frt: measured,
            mat_bytes: stores.iter().map(|s| s.bytes()).collect(),
            region_order: order,
            initial_workers,
            final_workers: current,
            replans,
            region_completed_at,
        }
    }

    /// Observe completed regions, fold the observations into the cost
    /// model, re-assign worker counts for the remaining regions under
    /// the budget, and apply the deltas through the engine's fenced
    /// scale protocol. Returns the trail entry.
    #[allow(clippy::too_many_arguments)]
    fn replan_remaining(
        &self,
        exec: &Execution,
        m: &Materialized,
        g: &RegionGraph,
        remaining: &[usize],
        about_to_activate: usize,
        completed_regions: &HashSet<usize>,
        stores: &[MatStore],
        initial_rows: &[f64],
        cost: &mut CostParams,
        current: &mut [usize],
        pinned_ops: &mut HashSet<usize>,
        unscalable: &mut HashSet<usize>,
        started: Instant,
    ) -> RegionPlan {
        let mw = &m.workflow;
        // --- observe -----------------------------------------------------
        let mut produced: HashMap<usize, u64> = HashMap::new();
        let mut busy: HashMap<usize, (u64, u64)> = HashMap::new(); // (busy_ns, processed)
        for (id, st) in exec.stats() {
            *produced.entry(id.op).or_insert(0) += st.produced;
            let b = busy.entry(id.op).or_insert((0, 0));
            b.0 += st.busy_ns;
            b.1 += st.processed;
        }
        let writer_ops: HashSet<usize> = m.writers.iter().copied().collect();
        let mut observed = Vec::new();
        for &r in completed_regions {
            for &op in &g.regions[r].ops {
                // MatWriters never emit — their observation is the store
                // row count, folded in via the links loop below; pinning
                // their zero `produced` would pollute the trail with
                // spurious infinite q-errors.
                if writer_ops.contains(&op) {
                    continue;
                }
                if !pinned_ops.insert(op) {
                    continue;
                }
                let rows = produced.get(&op).copied().unwrap_or(0) as f64;
                cost.pinned_rows.insert(op, rows);
                if mw.ops[op].is_source {
                    cost.source_rows.insert(op, rows);
                }
                // Calibrate per-tuple cost from observed busy time
                // (µs/tuple), replacing the configured default for this
                // operator in every later re-plan.
                let tuple_cost_us = match busy.get(&op) {
                    Some(&(ns, processed)) if processed > 0 => {
                        let us = ns as f64 / processed as f64 / 1000.0;
                        cost.tuple_cost.insert(op, us);
                        Some(us)
                    }
                    _ => None,
                };
                observed.push(ObservedOp {
                    op,
                    estimated_rows: initial_rows[op],
                    observed_rows: rows,
                    q_error: q_error(initial_rows[op], rows),
                    tuple_cost_us,
                });
            }
        }
        // Finished materialization stores: exact cardinality and tuple
        // width entering the reader's region.
        let mut widths = Vec::new();
        for (li, &(writer, reader)) in m.links.iter().enumerate() {
            let writer_region = crate::maestro::region::region_of(&g.regions, writer);
            if !completed_regions.contains(&writer_region) {
                continue;
            }
            let rows = stores[li].rows() as f64;
            cost.source_rows.insert(reader, rows);
            cost.pinned_rows.entry(reader).or_insert(rows);
            if let Some(wid) = stores[li].mean_bytes_per_tuple() {
                widths.push(wid);
            }
        }
        if !widths.is_empty() {
            cost.bytes_per_tuple = widths.iter().sum::<f64>() / widths.len() as f64;
        }
        // Calibrate the spill-plane bandwidth from what the completed
        // regions actually spilled and read back (µs/byte, same unit
        // as the tuple-cost calibration above). Executions that never
        // went over budget leave the configured constant in place.
        cost.calibrate_spill(&exec.spill_stats());
        // Readers of *unfinished* writers: estimate their cardinality
        // from the rows entering the paired writer so a link whose
        // writer region is still pending doesn't fall back to the
        // unknown-source default mid-replan. Links whose writer region
        // completed are skipped — their exact store row counts were
        // just installed above.
        crate::maestro::cost::seed_reader_rows(m, cost, |writer, _| {
            let wr = crate::maestro::region::region_of(&g.regions, writer);
            completed_regions.contains(&wr)
        });
        // --- re-plan -----------------------------------------------------
        let rows_out = cardinalities(mw, cost);
        let remaining_regions: Vec<crate::maestro::region::Region> = remaining
            .iter()
            .map(|&r| g.regions[r].clone())
            .collect();
        // Universal elasticity: no operator class is structurally
        // pinned anymore (sources split their scan ranges,
        // scatter-merge ops carry an epoch-keyed barrier,
        // broadcast-input ops replicate the build side). Only operators
        // whose scale request the engine actually refused stay fixed.
        let mut fixed: HashMap<usize, usize> = HashMap::new();
        for r in &remaining_regions {
            for &op in &r.ops {
                if unscalable.contains(&op) {
                    fixed.insert(op, current[op]);
                }
            }
        }
        let assigned = crate::maestro::cost::assign_workers(
            mw,
            &remaining_regions,
            &rows_out,
            cost,
            self.budget(),
            &fixed,
        );
        // --- apply -------------------------------------------------------
        // One-to-one groups must keep equal counts (worker *i* feeds
        // worker *i*), so deltas apply group-atomically: if any member's
        // scale is refused, already-scaled members are rolled back and
        // the whole group is memoized as unscalable — a refusal (e.g.
        // the region drained through pipelined links and completed
        // without an explicit await, so the engine's completed-workers
        // guard fires) must not leave the group at mismatched
        // parallelism, and is never retried.
        let groups = crate::maestro::cost::one_to_one_groups(mw);
        let mut decisions = Vec::new();
        for r in &remaining_regions {
            for g_ops in groups.iter().filter(|g| g.iter().all(|op| r.contains(*op))) {
                let changes: Vec<(usize, usize, usize)> = g_ops
                    .iter()
                    .map(|&op| (op, current[op], assigned[op]))
                    .filter(|&(op, from, to)| to != from && !fixed.contains_key(&op))
                    .collect();
                if changes.is_empty() {
                    continue;
                }
                let mut refused = false;
                let mut done: Vec<(usize, usize)> = Vec::new(); // (op, from)
                for &(op, from, to) in &changes {
                    let fence = exec.scale_operator(op, to);
                    let applied = fence > Duration::ZERO;
                    if applied {
                        current[op] = to;
                        done.push((op, from));
                    } else {
                        refused = true;
                    }
                    decisions.push(ScaleDecision {
                        op,
                        from,
                        to,
                        fence_ms: fence.as_secs_f64() * 1e3,
                        applied,
                    });
                    if refused {
                        break;
                    }
                }
                if refused {
                    // Roll back so the group keeps one count. A rollback
                    // that is itself refused leaves the mismatch (the
                    // group is pinned below, so it is never widened).
                    for &(op, from) in done.iter().rev() {
                        if exec.scale_operator(op, from) > Duration::ZERO {
                            current[op] = from;
                            if let Some(d) =
                                decisions.iter_mut().rev().find(|d| d.op == op)
                            {
                                d.applied = false;
                            }
                        }
                    }
                    for &op in g_ops {
                        unscalable.insert(op);
                    }
                }
            }
        }
        RegionPlan {
            region: about_to_activate,
            at: started.elapsed().as_secs_f64(),
            observed,
            workers: current.to_vec(),
            decisions,
            mid_region: false,
            migration_steps: Vec::new(),
        }
    }

    /// Probe-stream-driven **mid-region** re-plan: runs when the
    /// just-activated region is still executing after the grace window
    /// ([`mid_replan_after_ms`](Self::mid_replan_after_ms)). Live
    /// per-worker produced counts — the probe stream of the active
    /// region — are pinned into a *scratch* cost model (they are lower
    /// bounds, so they never enter the cross-region calibration), the
    /// remaining regions' counts are re-assigned, and a differing
    /// assignment for the active region is applied as **one fenced
    /// migration** ([`PlanDelta::Replan`]) so a refusal rolls the
    /// whole batch back. Returns the trail entry (`None` when nothing
    /// was observed or nothing changed).
    ///
    /// [`PlanDelta::Replan`]: crate::engine::migrate::PlanDelta
    #[allow(clippy::too_many_arguments)]
    fn mid_region_replan(
        &self,
        exec: &Execution,
        m: &Materialized,
        g: &RegionGraph,
        remaining: &[usize],
        active: usize,
        initial_rows: &[f64],
        cost: &CostParams,
        current: &mut [usize],
        unscalable: &mut HashSet<usize>,
        started: Instant,
    ) -> Option<RegionPlan> {
        let mw = &m.workflow;
        // --- observe the live probe stream ---------------------------
        let mut produced: HashMap<usize, u64> = HashMap::new();
        let mut busy: HashMap<usize, (u64, u64)> = HashMap::new();
        for (id, st) in exec.stats() {
            *produced.entry(id.op).or_insert(0) += st.produced;
            let b = busy.entry(id.op).or_insert((0, 0));
            b.0 += st.busy_ns;
            b.1 += st.processed;
        }
        let writer_ops: HashSet<usize> = m.writers.iter().copied().collect();
        let mut live_cost = cost.clone();
        let mut observed = Vec::new();
        for &op in &g.regions[active].ops {
            if writer_ops.contains(&op) {
                continue;
            }
            let rows = produced.get(&op).copied().unwrap_or(0) as f64;
            if rows <= 0.0 {
                continue;
            }
            live_cost.pinned_rows.insert(op, rows);
            if mw.ops[op].is_source {
                live_cost.source_rows.insert(op, rows);
            }
            let tuple_cost_us = match busy.get(&op) {
                Some(&(ns, n)) if n > 0 => {
                    let us = ns as f64 / n as f64 / 1000.0;
                    live_cost.tuple_cost.insert(op, us);
                    Some(us)
                }
                _ => None,
            };
            observed.push(ObservedOp {
                op,
                estimated_rows: initial_rows[op],
                observed_rows: rows,
                q_error: q_error(initial_rows[op], rows),
                tuple_cost_us,
            });
        }
        if observed.is_empty() {
            return None;
        }
        // --- re-plan -------------------------------------------------
        let rows_out = cardinalities(mw, &live_cost);
        let remaining_regions: Vec<crate::maestro::region::Region> =
            remaining.iter().map(|&r| g.regions[r].clone()).collect();
        let mut fixed: HashMap<usize, usize> = HashMap::new();
        for r in &remaining_regions {
            for &op in &r.ops {
                if unscalable.contains(&op) {
                    fixed.insert(op, current[op]);
                }
            }
        }
        let assigned = crate::maestro::cost::assign_workers(
            mw,
            &remaining_regions,
            &rows_out,
            &live_cost,
            self.budget(),
            &fixed,
        );
        // --- apply, active region only, as one fenced migration ------
        let groups = crate::maestro::cost::one_to_one_groups(mw);
        let active_region = &g.regions[active];
        let mut changes: Vec<(usize, usize, usize)> = Vec::new();
        let mut change_groups: Vec<Vec<usize>> = Vec::new();
        for g_ops in groups
            .iter()
            .filter(|g| g.iter().all(|op| active_region.contains(*op)))
        {
            let c: Vec<(usize, usize, usize)> = g_ops
                .iter()
                .map(|&op| (op, current[op], assigned[op]))
                .filter(|&(op, from, to)| to != from && !fixed.contains_key(&op))
                .collect();
            if !c.is_empty() {
                changes.extend(c);
                change_groups.push(g_ops.clone());
            }
        }
        if changes.is_empty() {
            return None;
        }
        let outcome = exec.migrate(crate::engine::migrate::PlanDelta::Replan {
            workers: changes.iter().map(|&(op, _, to)| (op, to)).collect(),
        });
        let mut decisions = Vec::new();
        for (i, &(op, from, to)) in changes.iter().enumerate() {
            let step = outcome.steps.get(i);
            let applied = outcome.applied && step.is_some_and(|s| s.applied);
            if applied {
                current[op] = to;
            }
            decisions.push(ScaleDecision {
                op,
                from,
                to,
                fence_ms: step.map_or(0.0, |s| s.fence.as_secs_f64() * 1e3),
                applied,
            });
        }
        if !outcome.applied {
            // The sequence aborted (any applied prefix was rolled
            // back): counts are unchanged; never retry these groups.
            for g_ops in &change_groups {
                for &op in g_ops {
                    unscalable.insert(op);
                }
            }
        }
        Some(RegionPlan {
            region: active,
            at: started.elapsed().as_secs_f64(),
            observed,
            workers: current.to_vec(),
            decisions,
            mid_region: true,
            migration_steps: outcome.steps.iter().map(|s| s.desc.clone()).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::operators::basic::{Cmp, Filter};
    use crate::operators::{CollectSink, HashJoin, SinkHandle};
    use crate::tuple::{Tuple, Value};
    use crate::workloads::VecSource;

    /// Fig. 4.1 with real operators: scan replicates to two filters
    /// feeding build and probe of a strict join.
    fn fig_4_1_real(rows: usize) -> (Workflow, SinkHandle, usize) {
        let mut w = Workflow::new();
        let scan = w.add(OpSpec::source("scan", 2, move |idx, parts| {
            let data: Vec<Tuple> = (0..rows)
                .skip(idx)
                .step_by(parts)
                .map(|i| Tuple::new(vec![Value::Int((i % 50) as i64), Value::Int(i as i64)]))
                .collect();
            Box::new(VecSource::new(data))
        }));
        // filter1 (probe path): keep ~80%.
        let f1 = w.add(OpSpec::unary("filter1", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(1, Cmp::Ge, Value::Int(0)))
        }));
        // filter2 (build path): keep one row per key (val < 50).
        let f2 = w.add(OpSpec::unary("filter2", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Filter::new(1, Cmp::Lt, Value::Int(50)))
        }));
        // Strict join: errors if probe precedes build EOF — exactly the
        // situation Maestro must prevent.
        let j = w.add(OpSpec::binary(
            "join",
            2,
            [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
            vec![0],
            |_, _| Box::new(HashJoin::new(0, 0).strict()),
        ));
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(scan, f1, 0);
        w.connect(scan, f2, 0);
        w.connect(f2, j, 0);
        w.connect(f1, j, 1);
        w.connect(j, sink, 0);
        (w, handle, sink)
    }

    #[test]
    fn schedules_infeasible_workflow_correctly() {
        let rows = 5_000;
        let (w, handle, sink) = fig_4_1_real(rows);
        let mut cost = CostParams::new();
        cost.source_rows.insert(0, rows as f64);
        cost.selectivity.insert(2, 50.0 / rows as f64); // filter2 tiny
        let sched = MaestroScheduler::new(Config::for_tests(), cost);
        let outcome = sched.run(w, &[sink]);
        // The strict join never saw an early probe tuple, and results
        // are complete: every scanned row joins its key row.
        assert_eq!(handle.total(), rows as u64, "join results incomplete");
        assert!(!outcome.choice.is_empty(), "materialization was required");
        assert!(outcome.mat_bytes.iter().sum::<u64>() > 0);
        assert!(outcome.region_order.len() >= 2);
        assert!(outcome.measured_frt.is_finite());
        // Static run: no elasticity, counts untouched end to end.
        assert_eq!(outcome.initial_workers, outcome.final_workers);
        assert!(outcome.replans.is_empty());
        assert!(!outcome.region_completed_at.is_empty());
    }

    #[test]
    fn plan_picks_minimal_frt_choice() {
        let (w, _handle, sink) = fig_4_1_real(1000);
        let mut cost = CostParams::new();
        cost.source_rows.insert(0, 1000.0);
        cost.selectivity.insert(2, 0.05);
        let sched = MaestroScheduler::new(Config::for_tests(), cost.clone());
        let (choice, frt) = sched.plan(&w, &[sink]);
        // Verify optimality among enumerated choices.
        let choices = enumerate_choices(&w, 3);
        for c in &choices {
            let (f, _) = crate::maestro::cost::first_response_time(&w, c, &cost, &[sink]);
            assert!(f >= frt - 1e-9, "plan missed better choice {c:?}");
        }
        assert!(choices.contains(&choice));
    }

    #[test]
    fn feasible_workflow_runs_without_materialization() {
        // Separate build/probe scans: no cycle, empty choice.
        let mut w = Workflow::new();
        let b = w.add(OpSpec::source("build", 1, |_, _| {
            Box::new(VecSource::new(
                (0..10).map(|k| Tuple::new(vec![Value::Int(k)])).collect(),
            ))
        }));
        let p = w.add(OpSpec::source("probe", 1, |_, _| {
            Box::new(VecSource::new(
                (0..100).map(|i| Tuple::new(vec![Value::Int(i % 10)])).collect(),
            ))
        }));
        let j = w.add(OpSpec::binary(
            "join",
            2,
            [PartitionScheme::Hash { key: 0 }, PartitionScheme::Hash { key: 0 }],
            vec![0],
            |_, _| Box::new(HashJoin::new(0, 0).strict()),
        ));
        let handle = SinkHandle::new(0);
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(CollectSink::new(h2.clone()))
        }));
        w.connect(b, j, 0);
        w.connect(p, j, 1);
        w.connect(j, sink, 0);
        let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
        let outcome = sched.run(w, &[sink]);
        assert!(outcome.choice.is_empty());
        assert_eq!(handle.total(), 100);
        assert_eq!(outcome.mat_bytes.len(), 0);
    }

    /// A sink that delays before recording (and reporting) its first
    /// result: `measured_frt` must reflect the first sink *output*, not
    /// the first tuple *arriving* at the sink.
    struct SlowSink {
        inner: CollectSink,
        delay_ms: u64,
        delayed: bool,
    }

    impl Operator for SlowSink {
        fn name(&self) -> &str {
            "slow_sink"
        }
        fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter) {
            if !self.delayed {
                self.delayed = true;
                std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
            }
            self.inner.process(t, port, out);
        }
    }

    #[test]
    fn measured_frt_is_first_sink_output_not_input_arrival() {
        const DELAY_MS: u64 = 150;
        let handle = SinkHandle::new(0);
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 1, |_, _| {
            Box::new(VecSource::new(
                (0..100).map(|i| Tuple::new(vec![Value::Int(i)])).collect(),
            ))
        }));
        let h2 = handle.clone();
        let sink = w.add(OpSpec::unary("sink", 1, PartitionScheme::RoundRobin, move |_, _| {
            Box::new(SlowSink {
                inner: CollectSink::new(h2.clone()),
                delay_ms: DELAY_MS,
                delayed: false,
            })
        }));
        w.connect(s, sink, 0);
        let sched = MaestroScheduler::new(Config::for_tests(), CostParams::new());
        let outcome = sched.run(w, &[sink]);
        assert_eq!(handle.total(), 100);
        // The scan's first output lands almost immediately; the sink's
        // first *result* is at least DELAY_MS later. Under the old
        // (input-arrival) definition this assertion fails.
        let upstream = outcome.summary.first_output[&s];
        assert!(
            outcome.measured_frt >= upstream + (DELAY_MS as f64 / 1e3) * 0.5,
            "measured_frt {} vs upstream first output {upstream}",
            outcome.measured_frt
        );
    }
}

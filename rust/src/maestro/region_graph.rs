//! The region graph (§4.4.2): dependencies between regions.
//!
//! For every blocking link u→v, region(u) must execute to completion
//! before region(v) may start (the destination needs the entire input
//! on that link first). A cycle in this graph means **no feasible
//! schedule exists** (Fig. 4.8) — e.g. when the same region produces
//! both the build and the probe input of a join — and the workflow
//! must be modified by materializing a pipelined link (§4.4.3).

use crate::engine::dag::Workflow;
use crate::maestro::region::{region_of, regions_of, Region};

/// Regions plus dependency edges (from-region must finish first).
#[derive(Clone, Debug)]
pub struct RegionGraph {
    pub regions: Vec<Region>,
    /// (upstream region, downstream region, workflow edge idx) per
    /// blocking link.
    pub deps: Vec<(usize, usize, usize)>,
}

impl RegionGraph {
    /// Self-dependencies and longer cycles make scheduling infeasible.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Topological order of region ids, or None if cyclic.
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let n = self.regions.len();
        let mut indeg = vec![0usize; n];
        for (u, v, _) in &self.deps {
            if u == v {
                return None; // self-loop
            }
            indeg[*v] += 1;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        // Deterministic order: lowest id first.
        queue.sort_unstable();
        let mut order = Vec::with_capacity(n);
        while let Some(&r) = queue.first() {
            queue.remove(0);
            order.push(r);
            let mut newly = Vec::new();
            for (u, v, _) in &self.deps {
                if *u == r {
                    indeg[*v] -= 1;
                    if indeg[*v] == 0 {
                        newly.push(*v);
                    }
                }
            }
            newly.sort_unstable();
            for x in newly {
                let pos = queue.binary_search(&x).unwrap_or_else(|p| p);
                queue.insert(pos, x);
            }
        }
        if order.len() == n {
            Some(order)
        } else {
            None
        }
    }

    /// Regions that must *fully complete* before `target` can start
    /// (transitive predecessors).
    pub fn ancestors(&self, target: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut stack = vec![target];
        while let Some(r) = stack.pop() {
            for (u, v, _) in &self.deps {
                if *v == r && !out.contains(u) {
                    out.push(*u);
                    stack.push(*u);
                }
            }
        }
        out.sort_unstable();
        out
    }
}

/// Build the region graph of a workflow.
pub fn region_graph(w: &Workflow) -> RegionGraph {
    region_graph_ext(w, &[])
}

/// Region graph with extra ordering constraints: `links` are
/// (producer op, consumer op) pairs where the producer's region must
/// fully complete before the consumer's region starts — materialized
/// writer→reader couples (§4.4.3).
pub fn region_graph_ext(w: &Workflow, links: &[(usize, usize)]) -> RegionGraph {
    let regions = regions_of(w);
    let mut deps = Vec::new();
    for (ei, e) in w.edges.iter().enumerate() {
        if w.is_blocking_edge(e) {
            let ru = region_of(&regions, e.from);
            let rv = region_of(&regions, e.to);
            deps.push((ru, rv, ei));
        }
    }
    for &(producer, consumer) in links {
        let ru = region_of(&regions, producer);
        let rv = region_of(&regions, consumer);
        deps.push((ru, rv, usize::MAX));
    }
    RegionGraph { regions, deps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::{OpSpec, Workflow};
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn src(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::source(name, 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }))
    }

    fn unary(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }))
    }

    fn join(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::binary(
            name,
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ))
    }

    /// The Fig. 4.1 pathology: scan → {filter1, filter2}; filter1 →
    /// probe, filter2 → build of the same join. Both filters share the
    /// scan's region, so the join's region depends on itself → cyclic.
    fn fig_4_1() -> Workflow {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f1 = unary(&mut w, "filter1");
        let f2 = unary(&mut w, "filter2");
        let j = join(&mut w, "join");
        let k = unary(&mut w, "sink");
        w.connect(s, f1, 0);
        w.connect(s, f2, 0);
        w.connect(f2, j, 0); // build (blocking)
        w.connect(f1, j, 1); // probe
        w.connect(j, k, 0);
        w
    }

    #[test]
    fn independent_build_region_is_acyclic() {
        let mut w = Workflow::new();
        let b = src(&mut w, "build_scan");
        let p = src(&mut w, "probe_scan");
        let j = join(&mut w, "join");
        let k = unary(&mut w, "sink");
        w.connect(b, j, 0);
        w.connect(p, j, 1);
        w.connect(j, k, 0);
        let g = region_graph(&w);
        assert!(g.is_acyclic());
        assert_eq!(g.deps.len(), 1);
        let order = g.topo_order().unwrap();
        // Build region first.
        let rb = region_of(&g.regions, b);
        let rj = region_of(&g.regions, j);
        let pos = |x: usize| order.iter().position(|&y| y == x).unwrap();
        assert!(pos(rb) < pos(rj));
    }

    #[test]
    fn self_dependency_detected_as_cycle() {
        let g = region_graph(&fig_4_1());
        assert!(!g.is_acyclic(), "Fig. 4.1 must yield a cyclic region graph");
        assert!(g.topo_order().is_none());
    }

    #[test]
    fn ancestors_are_transitive() {
        // chain: r0 →(blocking) r1 →(blocking) r2
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let g1 = w.add(
            OpSpec::unary("gb1", 1, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
                .with_blocking(vec![0]),
        );
        let g2 = w.add(
            OpSpec::unary("gb2", 1, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
                .with_blocking(vec![0]),
        );
        w.connect(s, g1, 0);
        w.connect(g1, g2, 0);
        let g = region_graph(&w);
        let r2 = region_of(&g.regions, g2);
        assert_eq!(g.ancestors(r2).len(), 2);
    }

    #[test]
    fn diamond_without_blocking_single_region() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f1 = unary(&mut w, "f1");
        let f2 = unary(&mut w, "f2");
        let u = w.add(OpSpec::binary(
            "union",
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![],
            |_, _| Box::new(Noop),
        ));
        w.connect(s, f1, 0);
        w.connect(s, f2, 0);
        w.connect(f1, u, 0);
        w.connect(f2, u, 1);
        let g = region_graph(&w);
        assert_eq!(g.regions.len(), 1);
        assert!(g.is_acyclic());
    }
}

//! Enumerating materialization choices (§4.5.1).
//!
//! A *choice* is a set of pipelined edges whose materialization makes
//! the region graph acyclic. We enumerate all **minimal** feasible
//! choices (no feasible proper subset) up to `max_edges` per choice —
//! the Fig. 4.11 walk over the sub-DAG between the replication point
//! and the join, generalized to arbitrary DAGs by searching candidate
//! edges of cyclic regions.
//!
//! The enumeration is parallelism-agnostic: each choice is later scored
//! by [`cost::best_choice`](crate::maestro::cost::best_choice) at the
//! workflow's authored worker counts, or — under a worker budget — by
//! [`cost::best_choice_elastic`](crate::maestro::cost::best_choice_elastic),
//! which pairs every choice here with its best per-region worker
//! assignment before comparing first response times.

use crate::engine::dag::Workflow;
use crate::maestro::cycles::{candidate_edges, feasible_with, is_feasible};

/// All minimal feasible materialization choices (each a sorted list of
/// edge indices). An already-feasible workflow yields one empty choice.
pub fn enumerate_choices(w: &Workflow, max_edges: usize) -> Vec<Vec<usize>> {
    if is_feasible(w) {
        return vec![Vec::new()];
    }
    let cands = candidate_edges(w);
    let mut found: Vec<Vec<usize>> = Vec::new();
    // Breadth over subset size → minimality by construction (a superset
    // of a found choice is pruned).
    for size in 1..=max_edges.min(cands.len()) {
        let mut subset = vec![0usize; size];
        enumerate_subsets(&cands, size, 0, &mut subset, 0, &mut |s: &[usize]| {
            if found.iter().any(|f| f.iter().all(|e| s.contains(e))) {
                return; // superset of a minimal choice
            }
            if feasible_with(w, s) {
                found.push(s.to_vec());
            }
        });
    }
    found
}

fn enumerate_subsets(
    cands: &[usize],
    size: usize,
    start: usize,
    subset: &mut Vec<usize>,
    depth: usize,
    f: &mut impl FnMut(&[usize]),
) {
    if depth == size {
        f(subset);
        return;
    }
    for i in start..cands.len() {
        subset[depth] = cands[i];
        enumerate_subsets(cands, size, i + 1, subset, depth + 1, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::engine::partitioner::PartitionScheme;
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _p: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn src(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::source(name, 1, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }))
    }

    fn unary(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::unary(name, 1, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }))
    }

    fn join(w: &mut Workflow, name: &str) -> usize {
        w.add(OpSpec::binary(
            name,
            1,
            [PartitionScheme::RoundRobin, PartitionScheme::RoundRobin],
            vec![0],
            |_, _| Box::new(Noop),
        ))
    }

    /// Fig. 4.1 again.
    fn fig_4_1() -> Workflow {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f1 = unary(&mut w, "filter1");
        let f2 = unary(&mut w, "filter2");
        let j = join(&mut w, "join");
        let k = unary(&mut w, "sink");
        w.connect(s, f1, 0); // e0 probe path
        w.connect(s, f2, 0); // e1 build path
        w.connect(f2, j, 0); // e2 build (blocking)
        w.connect(f1, j, 1); // e3 probe
        w.connect(j, k, 0); // e4
        w
    }

    #[test]
    fn feasible_workflow_needs_nothing() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let k = unary(&mut w, "sink");
        w.connect(s, k, 0);
        assert_eq!(enumerate_choices(&w, 3), vec![Vec::<usize>::new()]);
    }

    #[test]
    fn fig_4_1_has_single_edge_choices() {
        let w = fig_4_1();
        let choices = enumerate_choices(&w, 2);
        assert!(!choices.is_empty());
        // Minimal choices are single pipelined edges on the *probe*
        // path: {e0} (scan→filter1) or {e3} (filter1→probe) — the
        // Fig. 4.11-style enumeration along the probe feed.
        for c in &choices {
            assert_eq!(c.len(), 1, "choices should be minimal: {choices:?}");
        }
        let flat: Vec<usize> = choices.iter().map(|c| c[0]).collect();
        assert!(flat.contains(&0), "scan→filter1 choice missing: {flat:?}");
        assert!(flat.contains(&3), "filter1→probe choice missing: {flat:?}");
        assert!(
            !flat.contains(&1),
            "build-path materialization is not feasible: {flat:?}"
        );
    }

    #[test]
    fn all_choices_are_feasible() {
        let w = fig_4_1();
        for c in enumerate_choices(&w, 2) {
            assert!(crate::maestro::cycles::feasible_with(&w, &c), "{c:?}");
        }
    }

    /// Fig. 4.11-style: replicate feeding two joins' build+probe via
    /// shared paths → multiple distinct choices with different
    /// downstream consequences.
    #[test]
    fn two_join_workflow_multiple_choices() {
        let mut w = Workflow::new();
        let s = src(&mut w, "scan");
        let f = unary(&mut w, "filter");
        let j1 = join(&mut w, "j1");
        let j2 = join(&mut w, "j2");
        let k = unary(&mut w, "sink");
        // s replicates to f (probe chain) and j1 build; j1 output is
        // probe of j2; f feeds j2 build — a cyclic region.
        w.connect(s, f, 0); // e0
        w.connect(s, j1, 0); // e1 build j1 (blocking)
        w.connect(f, j1, 1); // e2 probe j1
        w.connect(f, j2, 0); // e3 build j2 (blocking)
        w.connect(j1, j2, 1); // e4 probe j2
        w.connect(j2, k, 0); // e5
        let g = crate::maestro::region_graph::region_graph(&w);
        assert!(!g.is_acyclic());
        let choices = enumerate_choices(&w, 2);
        assert!(!choices.is_empty());
        for c in &choices {
            assert!(crate::maestro::cycles::feasible_with(&w, c));
        }
    }
}

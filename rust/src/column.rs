//! Columnar (struct-of-arrays) batch storage: typed column vectors
//! with validity masks, plus the hash / gather / transpose kernels and
//! the column builders the exchange uses to keep batches columnar
//! across worker boundaries.
//!
//! A [`crate::tuple::TupleBatch`] carries its tuples in one of two
//! physical layouts — row-major (`[Tuple]`, the original layout) or
//! columnar (a [`ColumnSet`]: one typed vector per field) — and lazily
//! converts between them, caching both. The columnar layout turns the
//! data plane's hot loops into tight, branch-light passes over
//! contiguous `i64`/`f64`/`Arc<str>` vectors:
//!
//! * **hashing** — [`Column::hash_range`] reproduces
//!   [`Value::stable_hash`] *byte-exactly* per element (same type tags,
//!   same SplitMix64 finalizer, same `-0.0` normalization), so hash
//!   routes, SBK key sets and keyed state scopes are identical whichever
//!   layout computed them — fault-tolerance replay (§2.6.2) depends on
//!   routes being byte-stable;
//! * **predicates** — operators read the raw vectors through
//!   [`Column::int_vals`] / [`Column::float_vals`] / [`Column::str_vals`]
//!   and evaluate comparisons without per-tuple enum dispatch;
//! * **gathers** — [`ColumnSet::gather`] applies a selection vector
//!   column-at-a-time (the exchange's scatter), and
//!   [`ColumnSet::project`] is O(arity): projection just `Arc`-clones
//!   the kept columns;
//! * **scatter buffers** — [`ColumnAppender`] is the per-destination
//!   output buffer of the exchange: it accepts rows, batch slices and
//!   gathered selections, keeps them columnar when it can, and degrades
//!   to a row buffer on ragged arity (or when the engine runs with
//!   `Config::columnar = false`).
//!
//! Validity masks: a typed column stores `Value::Null` as a default
//! scalar plus a `false` bit in an optional side mask (`None` = all
//! valid — the overwhelmingly common case pays nothing). Columns whose
//! values mix scalar types fall back to [`Column::Mixed`], a plain
//! `Vec<Value>` with row semantics.
//!
//! Every kernel here is observationally identical to the row path it
//! replaces; `rust/tests/properties.rs` fuzzes that equivalence and the
//! unit tests below pin the byte-exactness of hashes and byte sizes.

use crate::tuple::{hash_bytes, mix64, Tuple, TupleBatch, Value, TAG_FLOAT, TAG_INT, TAG_NULL};
use std::sync::Arc;

#[inline]
fn valid(validity: &Option<Vec<bool>>, i: usize) -> bool {
    match validity {
        Some(m) => m[i],
        None => true,
    }
}

/// Push one validity bit, materializing the mask lazily: while every
/// element is valid the mask stays `None`.
fn mask_push(validity: &mut Option<Vec<bool>>, len_before: usize, ok: bool) {
    match validity {
        Some(m) => m.push(ok),
        None => {
            if !ok {
                let mut m = vec![true; len_before];
                m.push(false);
                *validity = Some(m);
            }
        }
    }
}

/// Extend a validity mask with a source range (`None` src = all valid).
fn mask_extend(
    dst: &mut Option<Vec<bool>>,
    len_before: usize,
    added: usize,
    src: Option<&[bool]>,
) {
    if let Some(d) = dst.as_mut() {
        match src {
            Some(s) => d.extend_from_slice(s),
            None => d.resize(len_before + added, true),
        }
        return;
    }
    if let Some(s) = src {
        if s.iter().any(|&b| !b) {
            let mut m = vec![true; len_before];
            m.extend_from_slice(s);
            *dst = Some(m);
        }
    }
}

/// Extend a validity mask with gathered source bits.
fn mask_gather(
    dst: &mut Option<Vec<bool>>,
    len_before: usize,
    src: Option<&[bool]>,
    base: usize,
    sel: &[u32],
) {
    if let Some(d) = dst.as_mut() {
        match src {
            Some(s) => d.extend(sel.iter().map(|&i| s[base + i as usize])),
            None => d.resize(len_before + sel.len(), true),
        }
        return;
    }
    if let Some(s) = src {
        if sel.iter().any(|&i| !s[base + i as usize]) {
            let mut m = vec![true; len_before];
            m.extend(sel.iter().map(|&i| s[base + i as usize]));
            *dst = Some(m);
        }
    }
}

fn gathered_mask(validity: &Option<Vec<bool>>, base: usize, sel: &[u32]) -> Option<Vec<bool>> {
    let m = validity.as_ref()?;
    let g: Vec<bool> = sel.iter().map(|&i| m[base + i as usize]).collect();
    if g.iter().all(|&b| b) {
        None
    } else {
        Some(g)
    }
}

/// One typed column: a contiguous vector of one scalar type plus an
/// optional validity mask (`None` = all valid; a `false` bit reads as
/// [`Value::Null`]). Heterogeneous columns fall back to
/// [`Column::Mixed`].
#[derive(Clone, Debug)]
pub enum Column {
    /// `i64` values; invalid slots hold `0`.
    Int {
        /// The packed values.
        vals: Vec<i64>,
        /// Validity bits; `None` = all valid.
        validity: Option<Vec<bool>>,
    },
    /// `f64` values (bit-preserving, including NaN payloads and signed
    /// zeros); invalid slots hold `0.0`.
    Float {
        /// The packed values.
        vals: Vec<f64>,
        /// Validity bits; `None` = all valid.
        validity: Option<Vec<bool>>,
    },
    /// Shared strings; invalid slots hold the empty string.
    Str {
        /// The packed values.
        vals: Vec<Arc<str>>,
        /// Validity bits; `None` = all valid.
        validity: Option<Vec<bool>>,
    },
    /// Row-semantics fallback for columns mixing scalar types.
    Mixed {
        /// The values, verbatim.
        vals: Vec<Value>,
    },
}

impl Column {
    /// An empty column typed for `v` (`Null` starts as `Int`; a later
    /// non-int value promotes the column to `Mixed`).
    pub fn new_for(v: &Value) -> Column {
        match v {
            Value::Int(_) | Value::Null => Column::Int { vals: Vec::new(), validity: None },
            Value::Float(_) => Column::Float { vals: Vec::new(), validity: None },
            Value::Str(_) => Column::Str { vals: Vec::new(), validity: None },
        }
    }

    /// An empty column of the same variant as `self`.
    pub fn empty_like(&self) -> Column {
        match self {
            Column::Int { .. } => Column::Int { vals: Vec::new(), validity: None },
            Column::Float { .. } => Column::Float { vals: Vec::new(), validity: None },
            Column::Str { .. } => Column::Str { vals: Vec::new(), validity: None },
            Column::Mixed { .. } => Column::Mixed { vals: Vec::new() },
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            Column::Int { vals, .. } => vals.len(),
            Column::Float { vals, .. } => vals.len(),
            Column::Str { vals, .. } => vals.len(),
            Column::Mixed { vals } => vals.len(),
        }
    }

    /// Whether the column has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The element at `i`, re-materialized as a [`Value`].
    pub fn value_at(&self, i: usize) -> Value {
        match self {
            Column::Int { vals, validity } => {
                if valid(validity, i) {
                    Value::Int(vals[i])
                } else {
                    Value::Null
                }
            }
            Column::Float { vals, validity } => {
                if valid(validity, i) {
                    Value::Float(vals[i])
                } else {
                    Value::Null
                }
            }
            Column::Str { vals, validity } => {
                if valid(validity, i) {
                    Value::Str(vals[i].clone())
                } else {
                    Value::Null
                }
            }
            Column::Mixed { vals } => vals[i].clone(),
        }
    }

    /// The raw `i64` vector + mask, when this is an `Int` column.
    pub fn int_vals(&self) -> Option<(&[i64], Option<&[bool]>)> {
        match self {
            Column::Int { vals, validity } => Some((vals, validity.as_deref())),
            _ => None,
        }
    }

    /// The raw `f64` vector + mask, when this is a `Float` column.
    pub fn float_vals(&self) -> Option<(&[f64], Option<&[bool]>)> {
        match self {
            Column::Float { vals, validity } => Some((vals, validity.as_deref())),
            _ => None,
        }
    }

    /// The raw string vector + mask, when this is a `Str` column.
    pub fn str_vals(&self) -> Option<(&[Arc<str>], Option<&[bool]>)> {
        match self {
            Column::Str { vals, validity } => Some((vals, validity.as_deref())),
            _ => None,
        }
    }

    fn promote_to_mixed(&mut self) {
        if matches!(self, Column::Mixed { .. }) {
            return;
        }
        let vals: Vec<Value> = (0..self.len()).map(|i| self.value_at(i)).collect();
        *self = Column::Mixed { vals };
    }

    /// Append one value; a type mismatch promotes the column to
    /// `Mixed` (never lossy).
    pub fn push_value(&mut self, v: &Value) {
        let ok = match (&mut *self, v) {
            (Column::Int { vals, validity }, Value::Int(i)) => {
                vals.push(*i);
                if let Some(m) = validity {
                    m.push(true);
                }
                true
            }
            (Column::Int { vals, validity }, Value::Null) => {
                let before = vals.len();
                vals.push(0);
                mask_push(validity, before, false);
                true
            }
            (Column::Float { vals, validity }, Value::Float(f)) => {
                vals.push(*f);
                if let Some(m) = validity {
                    m.push(true);
                }
                true
            }
            (Column::Float { vals, validity }, Value::Null) => {
                let before = vals.len();
                vals.push(0.0);
                mask_push(validity, before, false);
                true
            }
            (Column::Str { vals, validity }, Value::Str(s)) => {
                vals.push(s.clone());
                if let Some(m) = validity {
                    m.push(true);
                }
                true
            }
            (Column::Str { vals, validity }, Value::Null) => {
                let before = vals.len();
                vals.push(Arc::from(""));
                mask_push(validity, before, false);
                true
            }
            (Column::Mixed { vals }, v) => {
                vals.push(v.clone());
                true
            }
            _ => false,
        };
        if !ok {
            self.promote_to_mixed();
            if let Column::Mixed { vals } = self {
                vals.push(v.clone());
            }
        }
    }

    /// Append `src[start..end]`; same-variant pairs take a bulk
    /// `extend_from_slice`, anything else goes element-wise through
    /// [`Column::push_value`] (promoting as needed).
    pub fn append_range(&mut self, src: &Column, start: usize, end: usize) {
        let bulk = match (&mut *self, src) {
            (Column::Int { vals: d, validity: dm }, Column::Int { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend_from_slice(&s[start..end]);
                mask_extend(dm, before, end - start, sm.as_ref().map(|m| &m[start..end]));
                true
            }
            (Column::Float { vals: d, validity: dm }, Column::Float { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend_from_slice(&s[start..end]);
                mask_extend(dm, before, end - start, sm.as_ref().map(|m| &m[start..end]));
                true
            }
            (Column::Str { vals: d, validity: dm }, Column::Str { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend_from_slice(&s[start..end]);
                mask_extend(dm, before, end - start, sm.as_ref().map(|m| &m[start..end]));
                true
            }
            (Column::Mixed { vals: d }, s) => {
                for i in start..end {
                    d.push(s.value_at(i));
                }
                true
            }
            _ => false,
        };
        if !bulk {
            for i in start..end {
                self.push_value(&src.value_at(i));
            }
        }
    }

    /// Append the gathered elements `src[base + sel[..]]` (the
    /// exchange's scatter: `sel` is a selection vector relative to a
    /// batch view starting at `base`).
    pub fn append_gather(&mut self, src: &Column, base: usize, sel: &[u32]) {
        let bulk = match (&mut *self, src) {
            (Column::Int { vals: d, validity: dm }, Column::Int { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend(sel.iter().map(|&i| s[base + i as usize]));
                mask_gather(dm, before, sm.as_deref(), base, sel);
                true
            }
            (Column::Float { vals: d, validity: dm }, Column::Float { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend(sel.iter().map(|&i| s[base + i as usize]));
                mask_gather(dm, before, sm.as_deref(), base, sel);
                true
            }
            (Column::Str { vals: d, validity: dm }, Column::Str { vals: s, validity: sm }) => {
                let before = d.len();
                d.extend(sel.iter().map(|&i| s[base + i as usize].clone()));
                mask_gather(dm, before, sm.as_deref(), base, sel);
                true
            }
            (Column::Mixed { vals: d }, s) => {
                for &i in sel {
                    d.push(s.value_at(base + i as usize));
                }
                true
            }
            _ => false,
        };
        if !bulk {
            for &i in sel {
                self.push_value(&src.value_at(base + i as usize));
            }
        }
    }

    /// A new column holding `self[base + sel[..]]`.
    pub fn gather(&self, base: usize, sel: &[u32]) -> Column {
        match self {
            Column::Int { vals, validity } => Column::Int {
                vals: sel.iter().map(|&i| vals[base + i as usize]).collect(),
                validity: gathered_mask(validity, base, sel),
            },
            Column::Float { vals, validity } => Column::Float {
                vals: sel.iter().map(|&i| vals[base + i as usize]).collect(),
                validity: gathered_mask(validity, base, sel),
            },
            Column::Str { vals, validity } => Column::Str {
                vals: sel.iter().map(|&i| vals[base + i as usize].clone()).collect(),
                validity: gathered_mask(validity, base, sel),
            },
            Column::Mixed { vals } => Column::Mixed {
                vals: sel.iter().map(|&i| vals[base + i as usize].clone()).collect(),
            },
        }
    }

    /// Append the [`Value::stable_hash`] of each element in
    /// `[start, end)` to `out` — byte-identical to hashing the
    /// re-materialized values, in one tight typed loop.
    pub fn hash_range(&self, start: usize, end: usize, out: &mut Vec<u64>) {
        out.reserve(end - start);
        match self {
            Column::Int { vals, validity: None } => {
                out.extend(vals[start..end].iter().map(|&v| mix64((v as u64) ^ TAG_INT)));
            }
            Column::Int { vals, validity: Some(m) } => {
                out.extend(vals[start..end].iter().zip(m[start..end].iter()).map(
                    |(&v, &ok)| {
                        if ok {
                            mix64((v as u64) ^ TAG_INT)
                        } else {
                            mix64(TAG_NULL)
                        }
                    },
                ));
            }
            Column::Float { vals, validity: None } => {
                out.extend(vals[start..end].iter().map(|&v| {
                    let bits = if v == 0.0 { 0 } else { v.to_bits() };
                    mix64(bits ^ TAG_FLOAT)
                }));
            }
            Column::Float { vals, validity: Some(m) } => {
                out.extend(vals[start..end].iter().zip(m[start..end].iter()).map(
                    |(&v, &ok)| {
                        if ok {
                            let bits = if v == 0.0 { 0 } else { v.to_bits() };
                            mix64(bits ^ TAG_FLOAT)
                        } else {
                            mix64(TAG_NULL)
                        }
                    },
                ));
            }
            Column::Str { vals, validity: None } => {
                out.extend(vals[start..end].iter().map(|s| hash_bytes(s.as_bytes())));
            }
            Column::Str { vals, validity: Some(m) } => {
                out.extend(vals[start..end].iter().zip(m[start..end].iter()).map(
                    |(s, &ok)| {
                        if ok {
                            hash_bytes(s.as_bytes())
                        } else {
                            mix64(TAG_NULL)
                        }
                    },
                ));
            }
            Column::Mixed { vals } => {
                out.extend(vals[start..end].iter().map(Value::stable_hash));
            }
        }
    }

    /// `Value::as_float().unwrap_or(0.0)` over `[start, end)` — the
    /// aggregation accumulators' numeric coercion, vectorized.
    pub fn float_or_zero_range(&self, start: usize, end: usize, out: &mut Vec<f64>) {
        out.reserve(end - start);
        match self {
            Column::Float { vals, validity: None } => out.extend_from_slice(&vals[start..end]),
            Column::Float { vals, validity: Some(m) } => {
                out.extend(
                    vals[start..end]
                        .iter()
                        .zip(m[start..end].iter())
                        .map(|(&v, &ok)| if ok { v } else { 0.0 }),
                );
            }
            Column::Int { vals, validity: None } => {
                out.extend(vals[start..end].iter().map(|&v| v as f64));
            }
            Column::Int { vals, validity: Some(m) } => {
                out.extend(
                    vals[start..end]
                        .iter()
                        .zip(m[start..end].iter())
                        .map(|(&v, &ok)| if ok { v as f64 } else { 0.0 }),
                );
            }
            Column::Str { .. } => out.resize(out.len() + (end - start), 0.0),
            Column::Mixed { vals } => {
                out.extend(vals[start..end].iter().map(|v| v.as_float().unwrap_or(0.0)));
            }
        }
    }

    /// Sum of [`Value::byte_size`] over `[start, end)`, matching the
    /// row layout's accounting exactly (a null costs 1 byte).
    pub fn byte_size_range(&self, start: usize, end: usize) -> usize {
        match self {
            Column::Int { validity: None, .. } | Column::Float { validity: None, .. } => {
                8 * (end - start)
            }
            Column::Int { validity: Some(m), .. } | Column::Float { validity: Some(m), .. } => {
                m[start..end].iter().map(|&ok| if ok { 8 } else { 1 }).sum()
            }
            Column::Str { vals, validity: None } => {
                vals[start..end].iter().map(|s| 16 + s.len()).sum()
            }
            Column::Str { vals, validity: Some(m) } => vals[start..end]
                .iter()
                .zip(m[start..end].iter())
                .map(|(s, &ok)| if ok { 16 + s.len() } else { 1 })
                .sum(),
            Column::Mixed { vals } => vals[start..end].iter().map(Value::byte_size).sum(),
        }
    }
}

/// The columnar layout of one batch: one [`Column`] per field, all the
/// same length. Columns are individually `Arc`-shared, so
/// [`ColumnSet::project`] and clones are zero-copy.
#[derive(Clone, Debug, Default)]
pub struct ColumnSet {
    /// The columns, in field order.
    pub cols: Vec<Arc<Column>>,
    len: usize,
}

impl ColumnSet {
    /// Assemble a set from owned columns (all must share `len`).
    pub fn new(cols: Vec<Column>, len: usize) -> ColumnSet {
        debug_assert!(cols.iter().all(|c| c.len() == len));
        ColumnSet { cols: cols.into_iter().map(Arc::new).collect(), len }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the set has no rows.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of columns.
    pub fn arity(&self) -> usize {
        self.cols.len()
    }

    /// Transpose a row slice. Returns `None` when the rows are ragged
    /// (mixed arities) — such batches stay row-major.
    pub fn from_rows(rows: &[Tuple]) -> Option<ColumnSet> {
        let Some(first) = rows.first() else {
            return Some(ColumnSet::default());
        };
        let arity = first.arity();
        if arity == 0 || rows.iter().any(|t| t.arity() != arity) {
            return None;
        }
        // Type each column from its first non-null value so a leading
        // null doesn't force a Mixed column.
        let mut cols: Vec<Column> = (0..arity)
            .map(|c| {
                let proto = rows
                    .iter()
                    .map(|t| t.get(c))
                    .find(|v| !matches!(v, Value::Null))
                    .unwrap_or(&Value::Null);
                Column::new_for(proto)
            })
            .collect();
        for t in rows {
            for (c, col) in cols.iter_mut().enumerate() {
                col.push_value(t.get(c));
            }
        }
        Some(ColumnSet::new(cols, rows.len()))
    }

    /// Re-materialize row `i`.
    pub fn row(&self, i: usize) -> Tuple {
        Tuple::new(self.cols.iter().map(|c| c.value_at(i)).collect())
    }

    /// Re-materialize rows `[start, end)`.
    pub fn to_rows(&self, start: usize, end: usize) -> Vec<Tuple> {
        (start..end).map(|i| self.row(i)).collect()
    }

    /// Zero-copy projection: the kept columns are `Arc`-cloned, no
    /// values move.
    pub fn project(&self, fields: &[usize]) -> ColumnSet {
        ColumnSet {
            cols: fields.iter().map(|&f| self.cols[f].clone()).collect(),
            len: self.len,
        }
    }

    /// Gather `sel` (indices relative to a view starting at `base`)
    /// out of every column.
    pub fn gather(&self, base: usize, sel: &[u32]) -> ColumnSet {
        ColumnSet {
            cols: self.cols.iter().map(|c| Arc::new(c.gather(base, sel))).collect(),
            len: sel.len(),
        }
    }

    /// Sum of [`Tuple::byte_size`] over rows `[start, end)`, without
    /// materializing them.
    pub fn byte_size_range(&self, start: usize, end: usize) -> usize {
        8 * (end - start)
            + self
                .cols
                .iter()
                .map(|c| c.byte_size_range(start, end))
                .sum::<usize>()
    }
}

#[derive(Debug)]
enum AppendState {
    Empty,
    Cols(Vec<Column>),
    Rows(Vec<Tuple>),
}

/// A growable batch buffer that keeps appended data columnar when it
/// can: the exchange's per-destination scatter buffer. Accepts single
/// rows ([`ColumnAppender::push_row`]), whole batch views
/// ([`ColumnAppender::append_batch`]) and gathered selections
/// ([`ColumnAppender::append_gather`]); degrades to a plain row buffer
/// on ragged arity or when constructed with `columnar = false` (the
/// retained row path, used by the equivalence tests and
/// `Config::columnar`).
#[derive(Debug)]
pub struct ColumnAppender {
    columnar: bool,
    len: usize,
    state: AppendState,
}

impl ColumnAppender {
    /// A new empty buffer; `columnar = false` pins it to row storage.
    pub fn new(columnar: bool) -> ColumnAppender {
        ColumnAppender { columnar, len: 0, state: AppendState::Empty }
    }

    /// Buffered tuple count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn degrade_to_rows(&mut self) {
        if let AppendState::Cols(cols) = &self.state {
            let rows: Vec<Tuple> = (0..self.len)
                .map(|i| Tuple::new(cols.iter().map(|c| c.value_at(i)).collect()))
                .collect();
            self.state = AppendState::Rows(rows);
        }
    }

    /// Append one tuple (cloned).
    pub fn push_row(&mut self, t: &Tuple) {
        match &mut self.state {
            AppendState::Rows(rows) => rows.push(t.clone()),
            AppendState::Cols(cols) => {
                if cols.len() == t.arity() {
                    for (c, col) in cols.iter_mut().enumerate() {
                        col.push_value(t.get(c));
                    }
                } else {
                    self.degrade_to_rows();
                    if let AppendState::Rows(rows) = &mut self.state {
                        rows.push(t.clone());
                    }
                }
            }
            AppendState::Empty => {
                if self.columnar && t.arity() > 0 {
                    let mut cols: Vec<Column> =
                        t.values.iter().map(Column::new_for).collect();
                    for (c, col) in cols.iter_mut().enumerate() {
                        col.push_value(t.get(c));
                    }
                    self.state = AppendState::Cols(cols);
                } else {
                    self.state = AppendState::Rows(vec![t.clone()]);
                }
            }
        }
        self.len += 1;
    }

    /// Append one tuple, taking ownership (avoids the clone on the row
    /// path).
    pub fn push_owned(&mut self, t: Tuple) {
        if let AppendState::Rows(rows) = &mut self.state {
            rows.push(t);
            self.len += 1;
            return;
        }
        if matches!(self.state, AppendState::Empty) && !(self.columnar && t.arity() > 0) {
            self.state = AppendState::Rows(vec![t]);
            self.len += 1;
            return;
        }
        self.push_row(&t);
    }

    fn try_append_columns(&mut self, b: &TupleBatch) -> bool {
        let Some(cv) = b.columns() else {
            return false;
        };
        if cv.set.arity() == 0 {
            return false;
        }
        if matches!(self.state, AppendState::Empty) && self.columnar {
            self.state =
                AppendState::Cols(cv.set.cols.iter().map(|c| c.empty_like()).collect());
        }
        let AppendState::Cols(cols) = &mut self.state else {
            return false;
        };
        if cols.len() != cv.set.arity() {
            return false;
        }
        for (c, col) in cols.iter_mut().enumerate() {
            col.append_range(&cv.set.cols[c], cv.start, cv.end);
        }
        self.len += b.len();
        true
    }

    /// Append every tuple of a batch view (bulk column copies when both
    /// sides are columnar with matching arity).
    pub fn append_batch(&mut self, b: &TupleBatch) {
        if b.is_empty() {
            return;
        }
        if self.try_append_columns(b) {
            return;
        }
        if let AppendState::Rows(rows) = &mut self.state {
            rows.extend_from_slice(b.as_slice());
            self.len += b.len();
            return;
        }
        for t in b.iter() {
            self.push_row(t);
        }
    }

    /// Append the selected tuples `b[sel[..]]` (`sel` relative to the
    /// batch view) — the exchange's per-destination gather.
    pub fn append_gather(&mut self, b: &TupleBatch, sel: &[u32]) {
        if sel.is_empty() {
            return;
        }
        if let Some(cv) = b.columns() {
            if cv.set.arity() > 0 {
                if matches!(self.state, AppendState::Empty) && self.columnar {
                    self.state = AppendState::Cols(
                        cv.set.cols.iter().map(|c| c.empty_like()).collect(),
                    );
                }
                if let AppendState::Cols(cols) = &mut self.state {
                    if cols.len() == cv.set.arity() {
                        for (c, col) in cols.iter_mut().enumerate() {
                            col.append_gather(&cv.set.cols[c], cv.start, sel);
                        }
                        self.len += sel.len();
                        return;
                    }
                }
            }
        }
        if let AppendState::Rows(rows) = &mut self.state {
            rows.extend(sel.iter().map(|&i| b.get(i as usize).clone()));
            self.len += sel.len();
            return;
        }
        for &i in sel {
            self.push_row(b.get(i as usize));
        }
    }

    /// Drain the buffer into a batch (columnar when the buffer stayed
    /// columnar) and reset to empty.
    pub fn take_batch(&mut self) -> TupleBatch {
        let len = self.len;
        self.len = 0;
        match std::mem::replace(&mut self.state, AppendState::Empty) {
            AppendState::Empty => TupleBatch::empty(),
            AppendState::Rows(rows) => TupleBatch::new(rows),
            AppendState::Cols(cols) => TupleBatch::from_columns(ColumnSet::new(cols, len)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_rows() -> Vec<Tuple> {
        vec![
            Tuple::new(vec![Value::Int(7), Value::Float(2.5), Value::str("abc")]),
            Tuple::new(vec![Value::Null, Value::Float(-0.0), Value::str("")]),
            Tuple::new(vec![Value::Int(-3), Value::Null, Value::str("abcdefgh")]),
            Tuple::new(vec![Value::Int(0), Value::Float(1.0), Value::Null]),
        ]
    }

    #[test]
    fn roundtrip_preserves_values() {
        let rows = sample_rows();
        let set = ColumnSet::from_rows(&rows).unwrap();
        assert_eq!(set.len(), rows.len());
        assert_eq!(set.arity(), 3);
        assert_eq!(set.to_rows(0, rows.len()), rows);
    }

    #[test]
    fn hash_range_matches_stable_hash() {
        let rows = sample_rows();
        let set = ColumnSet::from_rows(&rows).unwrap();
        for (c, col) in set.cols.iter().enumerate() {
            let mut got = Vec::new();
            col.hash_range(0, rows.len(), &mut got);
            let want: Vec<u64> =
                rows.iter().map(|t| t.get(c).stable_hash()).collect();
            assert_eq!(got, want, "column {c}");
            // Sub-ranges too (batch-view slicing).
            let mut sub = Vec::new();
            col.hash_range(1, 3, &mut sub);
            assert_eq!(sub, want[1..3]);
        }
    }

    #[test]
    fn byte_size_matches_rows() {
        let rows = sample_rows();
        let set = ColumnSet::from_rows(&rows).unwrap();
        let want: usize = rows.iter().map(Tuple::byte_size).sum();
        assert_eq!(set.byte_size_range(0, rows.len()), want);
        let want13: usize = rows[1..3].iter().map(Tuple::byte_size).sum();
        assert_eq!(set.byte_size_range(1, 3), want13);
    }

    #[test]
    fn mixed_type_column_promotes() {
        let rows = vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::str("x")]),
        ];
        let set = ColumnSet::from_rows(&rows).unwrap();
        assert!(matches!(&*set.cols[0], Column::Mixed { .. }));
        assert_eq!(set.to_rows(0, 2), rows);
    }

    #[test]
    fn ragged_rows_stay_row_major() {
        let rows = vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::Int(1), Value::Int(2)]),
        ];
        assert!(ColumnSet::from_rows(&rows).is_none());
    }

    #[test]
    fn gather_and_project() {
        let rows = sample_rows();
        let set = ColumnSet::from_rows(&rows).unwrap();
        let g = set.gather(0, &[3, 1]);
        assert_eq!(g.row(0), rows[3]);
        assert_eq!(g.row(1), rows[1]);
        let p = set.project(&[2, 0]);
        assert!(Arc::ptr_eq(&p.cols[0], &set.cols[2]), "projection is zero-copy");
        assert_eq!(p.row(2).get(1), rows[2].get(0));
    }

    #[test]
    fn float_or_zero_matches_as_float() {
        let rows = sample_rows();
        let set = ColumnSet::from_rows(&rows).unwrap();
        for (c, col) in set.cols.iter().enumerate() {
            let mut got = Vec::new();
            col.float_or_zero_range(0, rows.len(), &mut got);
            let want: Vec<f64> = rows
                .iter()
                .map(|t| t.get(c).as_float().unwrap_or(0.0))
                .collect();
            assert_eq!(got, want, "column {c}");
        }
    }

    #[test]
    fn appender_columnar_and_row_modes_agree() {
        let rows = sample_rows();
        let batch = TupleBatch::new(rows.clone());
        let mut col_app = ColumnAppender::new(true);
        let mut row_app = ColumnAppender::new(false);
        for a in [&mut col_app, &mut row_app] {
            a.push_row(&rows[0]);
            a.append_batch(&batch.slice(1, 3));
            a.append_gather(&batch, &[3, 0]);
        }
        assert_eq!(col_app.len(), row_app.len());
        let cb = col_app.take_batch();
        let rb = row_app.take_batch();
        assert!(cb.has_columns());
        assert!(!rb.has_columns());
        assert_eq!(cb, rb);
        assert!(col_app.is_empty());
    }

    #[test]
    fn appender_degrades_on_ragged_arity() {
        let mut a = ColumnAppender::new(true);
        a.push_row(&Tuple::new(vec![Value::Int(1), Value::Int(2)]));
        a.push_row(&Tuple::new(vec![Value::Int(3)]));
        let b = a.take_batch();
        assert_eq!(b.len(), 2);
        assert!(!b.has_columns());
        assert_eq!(b.get(1).get(0).as_int(), Some(3));
    }
}

//! Tweet dataset generator.
//!
//! Models the paper's 180M-tweet US corpus: each tweet has a location
//! (US state), month, day, text and follower count. The *location*
//! distribution reproduces the skew of Fig. 3.15a exactly where the
//! experiments depend on it:
//!
//! * California is the heaviest key;
//! * `CA : AZ = 6.85` and `CA : IL = 4.05` — the target ratios the
//!   Fig. 3.16/3.17 result-awareness experiments monitor;
//! * the remaining states follow a zipf-like tail.
//!
//! Months are skewed toward December vs October at roughly 4:1 to mirror
//! the running covid example (Fig. 3.1: "December tuples are almost four
//! times the tuples of October").

use super::TupleSource;
use crate::tuple::{FieldType, Schema, Tuple, Value};
use crate::util::Rng;
use std::sync::Arc;

/// Number of US states modeled (the paper's tweet experiments use 48–56
/// workers so that each state maps to one worker).
pub const NUM_STATES: usize = 50;

/// State indices for the keys the experiments monitor.
pub const CA: usize = 6; // "California (location 6)" — §3.7.2
pub const AZ: usize = 4; // "Arizona (location 4)"
pub const IL: usize = 17; // "Illinois (location 17)"
pub const TX: usize = 48; // "Texas (location 48)" — §3.7.5
// West Virginia: shares CA's worker pre-mitigation (co-located under
// `stable_hash % 8`, the reshape experiments' worker count).
pub const WV: usize = 32;

/// Paper ratios (§3.7.2): actual CA:AZ and CA:IL tweet-count ratios.
pub const CA_AZ_RATIO: f64 = 6.85;
pub const CA_IL_RATIO: f64 = 4.05;

/// Relative weight of each state's tweet volume.
pub fn state_weights() -> Vec<f64> {
    let mut w = vec![0.0; NUM_STATES];
    // Anchors taken from the paper's counts (CA 26M, AZ 3.8M, IL 6.5M of
    // 180M) — these fix the monitored ratios.
    w[CA] = 26.0;
    w[AZ] = 26.0 / CA_AZ_RATIO; // ≈ 3.8
    w[IL] = 26.0 / CA_IL_RATIO; // ≈ 6.42
    w[TX] = 20.0; // second-heaviest (§3.7.5 monitors CA and TX)
    w[WV] = 0.6; // small key co-located with CA's worker (§3.7.4)
    // Zipf-ish tail for the rest, calibrated so the total ≈ 180 units.
    let mut rank = 2.0;
    for i in 0..NUM_STATES {
        if w[i] == 0.0 {
            w[i] = 14.0 / (rank + 1.0);
            rank += 1.0;
        }
    }
    w
}

/// Cumulative distribution over states derived from [`state_weights`].
fn state_cdf() -> Vec<f64> {
    let w = state_weights();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

/// Schema: (id, location, month, day, text, follower_num).
pub fn schema() -> Schema {
    Schema::new(&[
        ("id", FieldType::Int),
        ("location", FieldType::Int),
        ("month", FieldType::Int),
        ("day", FieldType::Int),
        ("text", FieldType::Str),
        ("follower_num", FieldType::Int),
    ])
}

/// Field indices (hot paths use positions, not names).
pub const F_ID: usize = 0;
pub const F_LOCATION: usize = 1;
pub const F_MONTH: usize = 2;
pub const F_DAY: usize = 3;
pub const F_TEXT: usize = 4;
pub const F_FOLLOWERS: usize = 5;

const TEXT_POOL: &[&str] = &[
    "just tested positive for covid, staying home",
    "wildfire smoke everywhere today",
    "climate change is real, look at this fire season",
    "new slang just dropped: no cap fr fr",
    "measles outbreak reported near downtown",
    "watching the game tonight",
    "zika travel advisory for the summer",
    "blunt talk: this coffee is terrible",
    "covid cases rising again this month",
    "beautiful sunset over the bay",
];

/// Deterministic tweet source; partition `idx` of `parts` generates the
/// round-robin slice of the full id space so scan workers cover the
/// corpus disjointly.
pub struct TweetSource {
    total: usize,
    parts: usize,
    idx: usize,
    pos: usize,
    cdf: Vec<f64>,
    seed: u64,
}

impl TweetSource {
    pub fn new(total: usize, parts: usize, idx: usize, seed: u64) -> TweetSource {
        TweetSource { total, parts, idx, pos: 0, cdf: state_cdf(), seed }
    }

    /// Generate the tweet with global id `i` (pure function of id+seed).
    fn make(&self, i: usize) -> Tuple {
        let mut rng = Rng::new(self.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let u = rng.f64();
        let location = self
            .cdf
            .iter()
            .position(|&c| u <= c)
            .unwrap_or(NUM_STATES - 1) as i64;
        // Month: Dec:Oct ≈ 4:1 with the rest mild; day uniform and
        // increasing with id within a month so order-sensitive plots
        // (Fig. 3.4's line chart) have a meaningful input order.
        let m = rng.f64();
        let month = if m < 0.32 {
            12
        } else if m < 0.40 {
            10
        } else {
            // Uniform over the other ten months.
            const OTHERS: [i64; 10] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 11];
            OTHERS[rng.below(10) as usize]
        };
        let day = 1 + ((i / 1000) % 28) as i64;
        let text = TEXT_POOL[rng.below(TEXT_POOL.len() as u64) as usize];
        let followers = (rng.f64().powi(3) * 10_000.0) as i64;
        Tuple::new(vec![
            Value::Int(i as i64),
            Value::Int(location),
            Value::Int(month),
            Value::Int(day),
            Value::Str(Arc::from(text)),
            Value::Int(followers),
        ])
    }
}

impl TupleSource for TweetSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let i = self.idx + self.pos * self.parts;
        if i >= self.total {
            return None;
        }
        self.pos += 1;
        Some(self.make(i))
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }

    fn len_hint(&self) -> Option<usize> {
        let t = self.total;
        let (p, i) = (self.parts, self.idx);
        Some(if i >= t { 0 } else { (t - i + p - 1) / p })
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(TweetSource {
            total: self.total,
            parts: self.parts,
            idx: self.idx,
            pos: self.pos,
            cdf: self.cdf.clone(),
            seed: self.seed,
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        // Remaining ids are idx + p·parts for p ≥ pos; sub-range j takes
        // p ≡ pos + j (mod n), i.e. the same pure generator at a finer
        // stride — replay stays byte-identical per id.
        Some(
            (0..n)
                .map(|j| {
                    Box::new(TweetSource {
                        total: self.total,
                        parts: self.parts * n,
                        idx: self.idx + (self.pos + j) * self.parts,
                        pos: 0,
                        cdf: self.cdf.clone(),
                        seed: self.seed,
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// The "top slang words per location" dimension table joined against
/// tweets in W1 (§3.7.1): one row per state.
pub fn slang_table() -> Vec<Tuple> {
    (0..NUM_STATES as i64)
        .map(|loc| {
            Tuple::new(vec![
                Value::Int(loc),
                Value::Str(Arc::from(format!("slang_{loc}_a slang_{loc}_b"))),
            ])
        })
        .collect()
}

/// Schema of [`slang_table`]: (location, slang).
pub fn slang_schema() -> Schema {
    Schema::new(&[("location", FieldType::Int), ("slang", FieldType::Str)])
}

/// Monthly covid-case counts (running example of Fig. 3.1): one row per
/// month.
pub fn covid_cases_table() -> Vec<Tuple> {
    (1..=12i64)
        .map(|month| {
            Tuple::new(vec![Value::Int(month), Value::Int(month * 10_000)])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn location_counts(total: usize) -> Vec<usize> {
        let mut src = TweetSource::new(total, 1, 0, 7);
        let mut counts = vec![0usize; NUM_STATES];
        while let Some(t) = src.next_tuple() {
            counts[t.get(F_LOCATION).as_int().unwrap() as usize] += 1;
        }
        counts
    }

    #[test]
    fn ca_is_heaviest_state() {
        let counts = location_counts(200_000);
        let max = counts.iter().enumerate().max_by_key(|(_, c)| **c).unwrap().0;
        assert_eq!(max, CA);
    }

    #[test]
    fn monitored_ratios_match_paper() {
        let counts = location_counts(400_000);
        let ca_az = counts[CA] as f64 / counts[AZ] as f64;
        let ca_il = counts[CA] as f64 / counts[IL] as f64;
        assert!((ca_az - CA_AZ_RATIO).abs() / CA_AZ_RATIO < 0.1, "CA:AZ={ca_az}");
        assert!((ca_il - CA_IL_RATIO).abs() / CA_IL_RATIO < 0.1, "CA:IL={ca_il}");
    }

    #[test]
    fn december_about_4x_october() {
        let mut src = TweetSource::new(300_000, 1, 0, 7);
        let (mut dec, mut oct) = (0usize, 0usize);
        while let Some(t) = src.next_tuple() {
            match t.get(F_MONTH).as_int().unwrap() {
                12 => dec += 1,
                10 => oct += 1,
                _ => {}
            }
        }
        let ratio = dec as f64 / oct as f64;
        assert!((2.8..5.2).contains(&ratio), "Dec:Oct={ratio}");
    }

    #[test]
    fn partitions_disjoint_and_complete() {
        let total = 10_000;
        let mut all: Vec<i64> = Vec::new();
        for p in 0..4 {
            let mut src = TweetSource::new(total, 4, p, 7);
            while let Some(t) = src.next_tuple() {
                all.push(t.get(F_ID).as_int().unwrap());
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..total as i64).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_replay() {
        let mut a = TweetSource::new(1000, 2, 1, 42);
        let first: Vec<Tuple> = std::iter::from_fn(|| a.next_tuple()).collect();
        a.reset();
        let second: Vec<Tuple> = std::iter::from_fn(|| a.next_tuple()).collect();
        assert_eq!(first, second);
        assert_eq!(first.len(), a.len_hint().unwrap());
    }

    #[test]
    fn slang_covers_all_states() {
        assert_eq!(slang_table().len(), NUM_STATES);
    }
}

//! Synthetic workload generators standing in for the paper's datasets.
//!
//! | Paper dataset | Generator | Used by |
//! |---|---|---|
//! | 180M US tweets (Fig 3.15a location skew) | [`tweets`] | Ch.2 W3, Ch.3 W1, Ch.4 |
//! | TPC-H SF-n (`lineitem`, `orders`, `customer`) | [`tpch`] | Ch.2 W1/W2, Ch.3 W3 |
//! | DSB (skewed TPC-DS; Figs 3.15d-f) | [`dsb`] | Ch.3 W2 |
//! | Synthetic changing-distribution pair | [`synthetic`] | Ch.3 W4 |
//!
//! All generators are deterministic functions of a seed (fault-tolerance
//! assumption A3 requires sources to replay identically). Sizes are
//! scaled down from cluster scale to single-machine scale; experiments
//! measure relative behaviour (ratios, percentiles, crossovers), which
//! the generators preserve by reproducing the papers' key distributions.

pub mod tweets;
pub mod tpch;
pub mod dsb;
pub mod synthetic;

use crate::tuple::Tuple;

/// A replayable source of tuples: deterministic, restartable, cheap to
/// clone. Scan operators wrap one of these.
pub trait TupleSource: Send {
    /// Next tuple, or `None` at end of (bounded) input.
    fn next_tuple(&mut self) -> Option<Tuple>;
    /// Reset to the beginning (checkpoint recovery replays sources).
    fn reset(&mut self);
    /// Total tuples this source will produce, if known.
    fn len_hint(&self) -> Option<usize>;
    /// Current read position (tuples already produced) — saved in
    /// checkpoints so recovery can [`seek`](TupleSource::seek) back.
    fn position(&self) -> usize;
    /// Jump to an absolute read position.
    fn seek(&mut self, pos: usize);
}

/// A source over a pre-materialized vector (used in tests and for small
/// dimension tables).
pub struct VecSource {
    data: std::sync::Arc<Vec<Tuple>>,
    pos: usize,
}

impl VecSource {
    pub fn new(data: Vec<Tuple>) -> VecSource {
        VecSource { data: std::sync::Arc::new(data), pos: 0 }
    }

    pub fn shared(data: std::sync::Arc<Vec<Tuple>>) -> VecSource {
        VecSource { data, pos: 0 }
    }
}

impl TupleSource for VecSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let t = self.data.get(self.pos).cloned();
        self.pos += 1;
        t
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.data.len())
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }
}

/// Split a source's index space across `n` partitions: partition `i`
/// takes rows `j` with `j % n == i` (round-robin partitioning of the
/// input file, like HDFS splits assigned to scan workers).
pub fn partition_range(total: usize, parts: usize, idx: usize) -> impl Iterator<Item = usize> {
    (0..total).skip(idx).step_by(parts.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Value;

    #[test]
    fn vec_source_replays() {
        let data = vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::Int(2)]),
        ];
        let mut s = VecSource::new(data);
        assert_eq!(s.len_hint(), Some(2));
        let a = s.next_tuple().unwrap();
        s.reset();
        let b = s.next_tuple().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn partition_range_covers_all_disjoint() {
        let mut seen = vec![false; 100];
        for p in 0..7 {
            for i in partition_range(100, 7, p) {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }
}

//! Synthetic workload generators standing in for the paper's datasets.
//!
//! | Paper dataset | Generator | Used by |
//! |---|---|---|
//! | 180M US tweets (Fig 3.15a location skew) | [`tweets`] | Ch.2 W3, Ch.3 W1, Ch.4 |
//! | TPC-H SF-n (`lineitem`, `orders`, `customer`) | [`tpch`] | Ch.2 W1/W2, Ch.3 W3 |
//! | DSB (skewed TPC-DS; Figs 3.15d-f) | [`dsb`] | Ch.3 W2 |
//! | Synthetic changing-distribution pair | [`synthetic`] | Ch.3 W4 |
//!
//! All generators are deterministic functions of a seed (fault-tolerance
//! assumption A3 requires sources to replay identically). Sizes are
//! scaled down from cluster scale to single-machine scale; experiments
//! measure relative behaviour (ratios, percentiles, crossovers), which
//! the generators preserve by reproducing the papers' key distributions.
//!
//! ## Splittable scan ranges
//!
//! Elastic scaling of *source* operators (engine::scale) needs the scan
//! range held by a mid-read worker to be repartitionable. Two optional
//! [`TupleSource`] methods provide that contract:
//!
//! * [`TupleSource::split`] — cut the **unread remainder** into `n`
//!   disjoint sub-sources whose multiset union equals the remainder.
//!   All built-in generators are stride views over a global id space
//!   (`id = idx + pos·parts`, each tuple a pure function of its id), so
//!   sub-source `j` is simply the same generator at
//!   `idx' = idx + (pos+j)·parts`, `parts' = n·parts` — replay from any
//!   recorded position in a sub-range is byte-identical to the unsplit
//!   stream (§2.6 assumption A3 survives the split).
//! * [`TupleSource::fork`] — clone the source at its current read
//!   position; quiesced checkpoints embed forks so recovery can
//!   re-deploy a post-scale worker set whose scan ranges no longer
//!   match any plan-time partitioning.
//!
//! Scale-down concatenates surrendered remainders with [`ChainSource`];
//! [`redistribute_sources`] is the engine-facing helper that maps `k`
//! surrendered remainders onto `n` workers using both.

pub mod tweets;
pub mod tpch;
pub mod dsb;
pub mod synthetic;

use crate::tuple::Tuple;

/// A replayable source of tuples: deterministic, restartable, cheap to
/// clone. Scan operators wrap one of these.
pub trait TupleSource: Send {
    /// Next tuple, or `None` at end of (bounded) input.
    fn next_tuple(&mut self) -> Option<Tuple>;
    /// Reset to the beginning (checkpoint recovery replays sources).
    fn reset(&mut self);
    /// Total tuples this source will produce, if known.
    fn len_hint(&self) -> Option<usize>;
    /// Current read position (tuples already produced) — saved in
    /// checkpoints so recovery can [`seek`](TupleSource::seek) back.
    fn position(&self) -> usize;
    /// Jump to an absolute read position.
    fn seek(&mut self, pos: usize);

    /// Clone this source **at its current read position**. Used by
    /// quiesced checkpoints (the snapshot embeds the fork so recovery
    /// replays the exact live range, even after elastic source scaling
    /// re-cut the ranges) and by test harnesses. `None` = not forkable;
    /// checkpoints then fall back to recording the position only.
    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        None
    }

    /// Split the **unread remainder** of this source into `n` disjoint
    /// sub-sources (each starting at position 0) whose multiset union
    /// equals the remainder. Every sub-source must itself satisfy the
    /// determinism/seek contract, so §2.6 replay stays byte-stable
    /// across the split. `None` = unsplittable; elastic scaling then
    /// hands the remainder to one worker whole and pads with empty
    /// sources (correct, just unbalanced).
    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        let _ = n;
        None
    }
}

/// A source over a pre-materialized vector (used in tests and for small
/// dimension tables). Generalized to a stride view (`global index =
/// start + pos·stride`) so [`TupleSource::split`] can re-cut it.
pub struct VecSource {
    data: std::sync::Arc<Vec<Tuple>>,
    start: usize,
    stride: usize,
    pos: usize,
}

impl VecSource {
    pub fn new(data: Vec<Tuple>) -> VecSource {
        VecSource { data: std::sync::Arc::new(data), start: 0, stride: 1, pos: 0 }
    }

    pub fn shared(data: std::sync::Arc<Vec<Tuple>>) -> VecSource {
        VecSource { data, start: 0, stride: 1, pos: 0 }
    }

    /// A stride view: rows `start, start+stride, start+2·stride, …`.
    pub fn strided(data: std::sync::Arc<Vec<Tuple>>, start: usize, stride: usize) -> VecSource {
        assert!(stride > 0);
        VecSource { data, start, stride, pos: 0 }
    }
}

impl TupleSource for VecSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let t = self.data.get(self.start + self.pos * self.stride).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn len_hint(&self) -> Option<usize> {
        let l = self.data.len();
        Some(if self.start >= l {
            0
        } else {
            (l - self.start + self.stride - 1) / self.stride
        })
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(VecSource {
            data: self.data.clone(),
            start: self.start,
            stride: self.stride,
            pos: self.pos,
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        Some(
            (0..n)
                .map(|j| {
                    Box::new(VecSource {
                        data: self.data.clone(),
                        start: self.start + (self.pos + j) * self.stride,
                        stride: self.stride * n,
                        pos: 0,
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// Concatenation of several sources: the *merge* side of the
/// split/merge contract. Elastic scale-down hands one worker the
/// remainders of several retired scan partitions as one chain.
/// Deterministic: parts are consumed in order.
///
/// The chain's position space starts at its **construction** point:
/// parts may already be mid-read (they are live remainders), so the
/// chain records each part's base position and `reset`/`seek` rewind
/// to *those*, never to the parts' absolute beginnings — position 0
/// of the chain is the first not-yet-consumed tuple, and replay can
/// never re-emit tuples the pre-scale worker already produced.
pub struct ChainSource {
    parts: Vec<Box<dyn TupleSource>>,
    /// Each part's read position at chain construction.
    bases: Vec<usize>,
    cur: usize,
    consumed: usize,
}

impl ChainSource {
    pub fn new(parts: Vec<Box<dyn TupleSource>>) -> ChainSource {
        let bases = parts.iter().map(|p| p.position()).collect();
        ChainSource { parts, bases, cur: 0, consumed: 0 }
    }
}

impl TupleSource for ChainSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        while self.cur < self.parts.len() {
            if let Some(t) = self.parts[self.cur].next_tuple() {
                self.consumed += 1;
                return Some(t);
            }
            self.cur += 1;
        }
        None
    }

    fn reset(&mut self) {
        for (p, &b) in self.parts.iter_mut().zip(&self.bases) {
            p.seek(b);
        }
        self.cur = 0;
        self.consumed = 0;
    }

    fn len_hint(&self) -> Option<usize> {
        // Tuples this chain will produce from its position 0: each
        // part's total minus what it had consumed before chaining.
        self.parts
            .iter()
            .zip(&self.bases)
            .map(|(p, &b)| p.len_hint().map(|l| l.saturating_sub(b)))
            .sum()
    }

    fn position(&self) -> usize {
        self.consumed
    }

    fn seek(&mut self, pos: usize) {
        self.reset();
        self.consumed = pos;
        let mut rest = pos;
        for (i, (p, &b)) in self.parts.iter_mut().zip(&self.bases).enumerate() {
            let cap = p
                .len_hint()
                .map(|l| l.saturating_sub(b))
                .unwrap_or(usize::MAX);
            if rest >= cap {
                p.seek(b + cap);
                rest -= cap;
            } else {
                p.seek(b + rest);
                self.cur = i;
                return;
            }
        }
        self.cur = self.parts.len();
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        let parts: Option<Vec<Box<dyn TupleSource>>> =
            self.parts.iter().map(|p| p.fork()).collect();
        parts.map(|parts| {
            Box::new(ChainSource {
                parts,
                bases: self.bases.clone(),
                cur: self.cur,
                consumed: self.consumed,
            }) as Box<dyn TupleSource>
        })
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        // Flatten to the remainders of the live parts, then let the
        // shared redistribution logic re-cut them.
        let mut live: Vec<Box<dyn TupleSource>> = Vec::new();
        for mut p in self.parts.drain(..) {
            match p.split(1) {
                Some(mut one) if one.len() == 1 => live.push(one.pop().unwrap()),
                _ => live.push(p),
            }
        }
        self.bases.clear();
        self.cur = 0;
        self.consumed = 0;
        Some(redistribute_sources(live, n))
    }
}

/// Map the unread remainders of `sources` (as surrendered by a scaled
/// source operator's old workers) onto exactly `n` workers:
///
/// * `k == n` — identity (each worker keeps one remainder);
/// * `k > n` — merge: remainders round-robin into `n` [`ChainSource`]s;
/// * `k < n` — split: each remainder is [`TupleSource::split`] into its
///   share of `n`; an unsplittable remainder stays whole and its share
///   is padded with empty sources (correct, just unbalanced).
///
/// The multiset union of the returned sources' outputs always equals
/// the union of the inputs' remainders — the invariant the elastic
/// scale fence needs for byte-identical sink multisets.
pub fn redistribute_sources(
    mut sources: Vec<Box<dyn TupleSource>>,
    n: usize,
) -> Vec<Box<dyn TupleSource>> {
    assert!(n > 0);
    if sources.is_empty() {
        return (0..n)
            .map(|_| Box::new(VecSource::new(Vec::new())) as Box<dyn TupleSource>)
            .collect();
    }
    let k = sources.len();
    if k == n {
        return sources;
    }
    if k > n {
        let mut buckets: Vec<Vec<Box<dyn TupleSource>>> = (0..n).map(|_| Vec::new()).collect();
        for (i, s) in sources.into_iter().enumerate() {
            buckets[i % n].push(s);
        }
        return buckets
            .into_iter()
            .map(|mut b| {
                if b.len() == 1 {
                    b.pop().unwrap()
                } else {
                    Box::new(ChainSource::new(b)) as Box<dyn TupleSource>
                }
            })
            .collect();
    }
    let mut out: Vec<Box<dyn TupleSource>> = Vec::with_capacity(n);
    for (i, mut s) in sources.drain(..).enumerate() {
        let share = n / k + usize::from(i < n % k);
        match s.split(share) {
            Some(subs) if subs.len() == share => out.extend(subs),
            _ => {
                out.push(s);
                for _ in 1..share {
                    out.push(Box::new(VecSource::new(Vec::new())));
                }
            }
        }
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// Split a source's index space across `n` partitions: partition `i`
/// takes rows `j` with `j % n == i` (round-robin partitioning of the
/// input file, like HDFS splits assigned to scan workers).
pub fn partition_range(total: usize, parts: usize, idx: usize) -> impl Iterator<Item = usize> {
    (0..total).skip(idx).step_by(parts.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Value;

    fn rows(n: usize) -> Vec<Tuple> {
        (0..n as i64).map(|i| Tuple::new(vec![Value::Int(i)])).collect()
    }

    fn drain(s: &mut dyn TupleSource) -> Vec<i64> {
        std::iter::from_fn(|| s.next_tuple())
            .map(|t| t.get(0).as_int().unwrap())
            .collect()
    }

    #[test]
    fn vec_source_replays() {
        let data = vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::Int(2)]),
        ];
        let mut s = VecSource::new(data);
        assert_eq!(s.len_hint(), Some(2));
        let a = s.next_tuple().unwrap();
        s.reset();
        let b = s.next_tuple().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn partition_range_covers_all_disjoint() {
        let mut seen = vec![false; 100];
        for p in 0..7 {
            for i in partition_range(100, 7, p) {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn vec_source_split_covers_remainder() {
        let mut s = VecSource::new(rows(23));
        for _ in 0..5 {
            s.next_tuple();
        }
        let mut union: Vec<i64> = Vec::new();
        for mut sub in s.split(3).unwrap() {
            union.extend(drain(sub.as_mut()));
        }
        union.sort_unstable();
        assert_eq!(union, (5..23).collect::<Vec<i64>>());
    }

    #[test]
    fn vec_source_fork_resumes_at_position() {
        let mut s = VecSource::new(rows(10));
        for _ in 0..4 {
            s.next_tuple();
        }
        let mut f = s.fork().unwrap();
        assert_eq!(drain(f.as_mut()), (4..10).collect::<Vec<i64>>());
        // The original is untouched.
        assert_eq!(s.position(), 4);
    }

    #[test]
    fn chain_source_concatenates_and_seeks() {
        let a = Box::new(VecSource::new(rows(4))) as Box<dyn TupleSource>;
        let b = Box::new(VecSource::new(rows(3))) as Box<dyn TupleSource>;
        let mut c = ChainSource::new(vec![a, b]);
        assert_eq!(c.len_hint(), Some(7));
        assert_eq!(drain(&mut c), vec![0, 1, 2, 3, 0, 1, 2]);
        assert_eq!(c.position(), 7);
        c.seek(5);
        assert_eq!(c.position(), 5);
        assert_eq!(drain(&mut c), vec![1, 2]);
        c.reset();
        assert_eq!(drain(&mut c).len(), 7);
    }

    #[test]
    fn chain_of_mid_read_parts_never_replays_consumed_tuples() {
        // Live remainders: a part consumed 2 of 5 before chaining. The
        // chain's position space must start at the remainder, so
        // reset/seek can never rewind into pre-chain territory.
        let mut a = VecSource::new(rows(5));
        a.next_tuple();
        a.next_tuple();
        let mut c = ChainSource::new(vec![
            Box::new(a) as Box<dyn TupleSource>,
            Box::new(VecSource::new(rows(3))) as Box<dyn TupleSource>,
        ]);
        assert_eq!(c.len_hint(), Some(6));
        assert_eq!(drain(&mut c), vec![2, 3, 4, 0, 1, 2]);
        c.seek(1);
        assert_eq!(drain(&mut c), vec![3, 4, 0, 1, 2]);
        c.reset();
        assert_eq!(drain(&mut c), vec![2, 3, 4, 0, 1, 2]);
    }

    #[test]
    fn redistribute_merges_and_splits() {
        // 3 remainders → 2 workers: chained, nothing lost.
        let srcs: Vec<Box<dyn TupleSource>> = (0..3)
            .map(|_| Box::new(VecSource::new(rows(5))) as Box<dyn TupleSource>)
            .collect();
        let mut merged = redistribute_sources(srcs, 2);
        assert_eq!(merged.len(), 2);
        let total: usize = merged.iter_mut().map(|s| drain(s.as_mut()).len()).sum();
        assert_eq!(total, 15);
        // 2 remainders → 5 workers: split, nothing lost or duplicated.
        let srcs: Vec<Box<dyn TupleSource>> = (0..2)
            .map(|_| Box::new(VecSource::new(rows(7))) as Box<dyn TupleSource>)
            .collect();
        let mut split = redistribute_sources(srcs, 5);
        assert_eq!(split.len(), 5);
        let total: usize = split.iter_mut().map(|s| drain(s.as_mut()).len()).sum();
        assert_eq!(total, 14);
    }
}

//! Synthetic changing-distribution workload (Ch. 3 workflow W4,
//! Fig. 3.24): a large stream whose key distribution shifts mid-run,
//! plus a small uniform dimension table.
//!
//! Paper setting (§3.7.8): both tables have 42 keys; the big table has
//! 80M rows (scaled down here). "For the first 20M tuples, 80% was
//! allotted to key 0 and the rest uniformly distributed among the
//! remaining keys. For the next 60M tuples, 60% was allotted to key 0,
//! 20% to key 10, and the rest uniformly distributed."

use super::TupleSource;
use crate::tuple::{FieldType, Schema, Tuple, Value};
use crate::util::Rng;

pub const NUM_KEYS: u64 = 42;
/// The key whose worker is skewed throughout.
pub const HOT_KEY: i64 = 0;
/// The key that becomes hot after the distribution change.
pub const SECOND_KEY: i64 = 10;

/// (key, value) schema shared by both tables.
pub fn schema() -> Schema {
    Schema::new(&[("key", FieldType::Int), ("value", FieldType::Int)])
}

pub const F_KEY: usize = 0;
pub const F_VALUE: usize = 1;

/// The big streaming table with the mid-run distribution shift at
/// `change_at` (fraction of `total`, 0.25 in the paper: 20M of 80M).
pub struct ShiftingSource {
    total: usize,
    parts: usize,
    idx: usize,
    pos: usize,
    seed: u64,
    change_at: usize,
}

impl ShiftingSource {
    pub fn new(total: usize, parts: usize, idx: usize, seed: u64) -> ShiftingSource {
        ShiftingSource { total, parts, idx, pos: 0, seed, change_at: total / 4 }
    }

    fn key_for(&self, i: usize, rng: &mut Rng) -> i64 {
        let u = rng.f64();
        if i < self.change_at {
            // Phase A: 80% key 0, 20% uniform over the other 41 keys.
            if u < 0.8 {
                HOT_KEY
            } else {
                other_key(rng, &[HOT_KEY])
            }
        } else {
            // Phase B: 60% key 0, 20% key 10, 20% uniform over the rest.
            if u < 0.6 {
                HOT_KEY
            } else if u < 0.8 {
                SECOND_KEY
            } else {
                other_key(rng, &[HOT_KEY, SECOND_KEY])
            }
        }
    }
}

fn other_key(rng: &mut Rng, excluded: &[i64]) -> i64 {
    loop {
        let k = rng.below(NUM_KEYS) as i64;
        if !excluded.contains(&k) {
            return k;
        }
    }
}

impl TupleSource for ShiftingSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let i = self.idx + self.pos * self.parts;
        if i >= self.total {
            return None;
        }
        self.pos += 1;
        let mut rng = Rng::new(self.seed ^ (i as u64).wrapping_mul(0x94D049BB133111EB));
        let key = self.key_for(i, &mut rng);
        Some(Tuple::new(vec![
            Value::Int(key),
            Value::Int(rng.below(1_000_000) as i64),
        ]))
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }

    fn len_hint(&self) -> Option<usize> {
        let (t, p, i) = (self.total, self.parts, self.idx);
        Some(if i >= t { 0 } else { (t - i + p - 1) / p })
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(ShiftingSource {
            total: self.total,
            parts: self.parts,
            idx: self.idx,
            pos: self.pos,
            seed: self.seed,
            change_at: self.change_at,
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        // `change_at` is a property of the *global* id space, so the
        // distribution shift lands at the same rows after the split.
        Some(
            (0..n)
                .map(|j| {
                    Box::new(ShiftingSource {
                        total: self.total,
                        parts: self.parts * n,
                        idx: self.idx + (self.pos + j) * self.parts,
                        pos: 0,
                        seed: self.seed,
                        change_at: self.change_at,
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// The small build-side table: 100 rows per key, uniform (the paper's
/// 4,200-row table over 42 keys).
pub fn dim_table(rows_per_key: usize) -> Vec<Tuple> {
    let mut out = Vec::with_capacity(NUM_KEYS as usize * rows_per_key);
    for k in 0..NUM_KEYS as i64 {
        for v in 0..rows_per_key as i64 {
            out.push(Tuple::new(vec![Value::Int(k), Value::Int(v)]));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_a_80_percent_hot() {
        let total = 40_000;
        let mut s = ShiftingSource::new(total, 1, 0, 5);
        let mut hot = 0usize;
        for _ in 0..total / 4 {
            let t = s.next_tuple().unwrap();
            if t.get(F_KEY).as_int() == Some(HOT_KEY) {
                hot += 1;
            }
        }
        let share = hot as f64 / (total / 4) as f64;
        assert!((0.75..0.85).contains(&share), "hot share {share}");
    }

    #[test]
    fn phase_b_60_20_split() {
        let total = 40_000;
        let mut s = ShiftingSource::new(total, 1, 0, 5);
        for _ in 0..total / 4 {
            s.next_tuple();
        }
        let (mut hot, mut second, mut n) = (0usize, 0usize, 0usize);
        while let Some(t) = s.next_tuple() {
            n += 1;
            match t.get(F_KEY).as_int().unwrap() {
                HOT_KEY => hot += 1,
                SECOND_KEY => second += 1,
                _ => {}
            }
        }
        let hs = hot as f64 / n as f64;
        let ss = second as f64 / n as f64;
        assert!((0.55..0.65).contains(&hs), "hot {hs}");
        assert!((0.15..0.25).contains(&ss), "second {ss}");
    }

    #[test]
    fn dim_table_uniform() {
        let t = dim_table(100);
        assert_eq!(t.len(), 4_200);
    }
}

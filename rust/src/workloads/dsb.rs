//! DSB-like generator (skew-enhanced TPC-DS): `web_sales` fact table and
//! the dimension tables of the Ch. 3 workflow W2 (based on TPC-DS query
//! 18: total count per item category for 2001 web sales by customers
//! with `birth_month >= 6`).
//!
//! Three join attributes with different skew (Figs. 3.15d–f):
//! `item_id` is **highly** skewed (zipf θ≈1.1), `date_id` **moderately**
//! skewed (θ≈0.5), `customer_id` mildly skewed.

use super::TupleSource;
use crate::tuple::{FieldType, Schema, Tuple, Value};
use crate::util::{Rng, Zipf};

pub const NUM_ITEMS: u64 = 2_000;
pub const NUM_DATES: u64 = 730; // two years of dates; year 2001 = first 365
pub const NUM_CUSTOMERS: u64 = 5_000;
pub const NUM_CATEGORIES: i64 = 10;

/// web_sales: (item_id, date_id, customer_id, quantity, price).
pub fn web_sales_schema() -> Schema {
    Schema::new(&[
        ("item_id", FieldType::Int),
        ("date_id", FieldType::Int),
        ("customer_id", FieldType::Int),
        ("quantity", FieldType::Int),
        ("price", FieldType::Float),
    ])
}

pub const WS_ITEM: usize = 0;
pub const WS_DATE: usize = 1;
pub const WS_CUSTOMER: usize = 2;
pub const WS_QUANTITY: usize = 3;
pub const WS_PRICE: usize = 4;

/// item: (item_id, category).
pub fn item_schema() -> Schema {
    Schema::new(&[("item_id", FieldType::Int), ("category", FieldType::Int)])
}

/// date_dim: (date_id, year).
pub fn date_schema() -> Schema {
    Schema::new(&[("date_id", FieldType::Int), ("year", FieldType::Int)])
}

/// customer: (customer_id, birth_month).
pub fn customer_schema() -> Schema {
    Schema::new(&[
        ("customer_id", FieldType::Int),
        ("birth_month", FieldType::Int),
    ])
}

/// Skew exponents for the three fact-table foreign keys.
#[derive(Clone, Copy, Debug)]
pub struct SkewProfile {
    pub item_theta: f64,
    pub date_theta: f64,
    pub customer_theta: f64,
}

impl Default for SkewProfile {
    fn default() -> SkewProfile {
        SkewProfile { item_theta: 1.1, date_theta: 0.5, customer_theta: 0.3 }
    }
}

/// Deterministic partitioned `web_sales` source.
pub struct WebSalesSource {
    total: usize,
    parts: usize,
    idx: usize,
    pos: usize,
    seed: u64,
    item_z: Zipf,
    date_z: Zipf,
    cust_z: Zipf,
}

impl WebSalesSource {
    pub fn new(
        total: usize,
        parts: usize,
        idx: usize,
        seed: u64,
        profile: SkewProfile,
    ) -> WebSalesSource {
        WebSalesSource {
            total,
            parts,
            idx,
            pos: 0,
            seed,
            item_z: Zipf::new(NUM_ITEMS, profile.item_theta),
            date_z: Zipf::new(NUM_DATES, profile.date_theta),
            cust_z: Zipf::new(NUM_CUSTOMERS, profile.customer_theta),
        }
    }
}

impl TupleSource for WebSalesSource {
    fn next_tuple(&mut self) -> Option<Tuple> {
        let i = self.idx + self.pos * self.parts;
        if i >= self.total {
            return None;
        }
        self.pos += 1;
        let mut rng = Rng::new(self.seed ^ (i as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        Some(Tuple::new(vec![
            Value::Int(self.item_z.sample(&mut rng) as i64),
            Value::Int(self.date_z.sample(&mut rng) as i64),
            Value::Int(self.cust_z.sample(&mut rng) as i64),
            Value::Int(1 + rng.below(10) as i64),
            Value::Float(5.0 + rng.f64() * 495.0),
        ]))
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn position(&self) -> usize {
        self.pos
    }

    fn seek(&mut self, pos: usize) {
        self.pos = pos;
    }

    fn len_hint(&self) -> Option<usize> {
        let (t, p, i) = (self.total, self.parts, self.idx);
        Some(if i >= t { 0 } else { (t - i + p - 1) / p })
    }

    fn fork(&self) -> Option<Box<dyn TupleSource>> {
        Some(Box::new(WebSalesSource {
            total: self.total,
            parts: self.parts,
            idx: self.idx,
            pos: self.pos,
            seed: self.seed,
            item_z: self.item_z.clone(),
            date_z: self.date_z.clone(),
            cust_z: self.cust_z.clone(),
        }))
    }

    fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
        assert!(n > 0);
        Some(
            (0..n)
                .map(|j| {
                    Box::new(WebSalesSource {
                        total: self.total,
                        parts: self.parts * n,
                        idx: self.idx + (self.pos + j) * self.parts,
                        pos: 0,
                        seed: self.seed,
                        item_z: self.item_z.clone(),
                        date_z: self.date_z.clone(),
                        cust_z: self.cust_z.clone(),
                    }) as Box<dyn TupleSource>
                })
                .collect(),
        )
    }
}

/// Dimension tables (small; materialized).
pub fn item_table() -> Vec<Tuple> {
    (0..NUM_ITEMS as i64)
        .map(|id| Tuple::new(vec![Value::Int(id), Value::Int(id % NUM_CATEGORIES)]))
        .collect()
}

pub fn date_table() -> Vec<Tuple> {
    (0..NUM_DATES as i64)
        .map(|id| {
            let year = if id < 365 { 2001 } else { 2002 };
            Tuple::new(vec![Value::Int(id), Value::Int(year)])
        })
        .collect()
}

pub fn customer_table(seed: u64) -> Vec<Tuple> {
    let mut rng = Rng::new(seed);
    (0..NUM_CUSTOMERS as i64)
        .map(|id| {
            Tuple::new(vec![Value::Int(id), Value::Int(1 + rng.below(12) as i64)])
        })
        .collect()
}

/// The "slang"-style category dimension used in docs/examples.
pub fn category_name(cat: i64) -> String {
    format!("category_{cat}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key_counts(field: usize, n: u64, rows: usize) -> Vec<usize> {
        let mut s = WebSalesSource::new(rows, 1, 0, 11, SkewProfile::default());
        let mut counts = vec![0usize; n as usize];
        while let Some(t) = s.next_tuple() {
            counts[t.get(field).as_int().unwrap() as usize] += 1;
        }
        counts
    }

    #[test]
    fn item_highly_skewed_date_moderate() {
        let items = key_counts(WS_ITEM, NUM_ITEMS, 60_000);
        let dates = key_counts(WS_DATE, NUM_DATES, 60_000);
        let top_item_share = *items.iter().max().unwrap() as f64 / 60_000.0;
        let top_date_share = *dates.iter().max().unwrap() as f64 / 60_000.0;
        assert!(
            top_item_share > 2.5 * top_date_share,
            "item {top_item_share} vs date {top_date_share}"
        );
    }

    #[test]
    fn dims_cover_fact_keys() {
        assert_eq!(item_table().len() as u64, NUM_ITEMS);
        assert_eq!(date_table().len() as u64, NUM_DATES);
        assert_eq!(customer_table(1).len() as u64, NUM_CUSTOMERS);
    }

    #[test]
    fn year_2001_is_half_of_dates() {
        let n_2001 = date_table()
            .iter()
            .filter(|t| t.get(1).as_int() == Some(2001))
            .count();
        assert_eq!(n_2001, 365);
    }

    #[test]
    fn deterministic_replay() {
        let mut s = WebSalesSource::new(5_000, 2, 1, 3, SkewProfile::default());
        let a: Vec<Tuple> = std::iter::from_fn(|| s.next_tuple()).collect();
        s.reset();
        let b: Vec<Tuple> = std::iter::from_fn(|| s.next_tuple()).collect();
        assert_eq!(a, b);
    }
}

//! TPC-H-like generator: `lineitem`, `orders`, `customer`.
//!
//! Used by the Ch. 2 workflows (W1 ≈ TPC-H Q1 over `lineitem`, W2 ≈ Q13
//! over `customer ⋈ orders`) and the Ch. 3 sort workflow W3 (range
//! partition of `orders` on `totalprice`, whose bell-shaped distribution
//! — Fig. 3.15b — is what makes equal-width ranges skewed).

use super::TupleSource;
use crate::tuple::{FieldType, Schema, Tuple, Value};
use crate::util::Rng;
use std::sync::Arc;

/// Rows per "scale-factor unit"; the paper's SF1 lineitem is 6M rows —
/// we scale 1 unit = `LINEITEM_PER_SF` rows for single-machine runs.
pub const LINEITEM_PER_SF: usize = 60_000;
pub const ORDERS_PER_SF: usize = 15_000;
pub const CUSTOMER_PER_SF: usize = 1_500;

/// lineitem: (orderkey, quantity, extendedprice, discount, tax,
/// returnflag, linestatus, shipdate).
pub fn lineitem_schema() -> Schema {
    Schema::new(&[
        ("orderkey", FieldType::Int),
        ("quantity", FieldType::Int),
        ("extendedprice", FieldType::Float),
        ("discount", FieldType::Float),
        ("tax", FieldType::Float),
        ("returnflag", FieldType::Str),
        ("linestatus", FieldType::Str),
        ("shipdate", FieldType::Int),
    ])
}

pub const L_ORDERKEY: usize = 0;
pub const L_QUANTITY: usize = 1;
pub const L_EXTENDEDPRICE: usize = 2;
pub const L_DISCOUNT: usize = 3;
pub const L_TAX: usize = 4;
pub const L_RETURNFLAG: usize = 5;
pub const L_LINESTATUS: usize = 6;
pub const L_SHIPDATE: usize = 7;

/// orders: (orderkey, custkey, orderstatus, totalprice, orderdate).
pub fn orders_schema() -> Schema {
    Schema::new(&[
        ("orderkey", FieldType::Int),
        ("custkey", FieldType::Int),
        ("orderstatus", FieldType::Str),
        ("totalprice", FieldType::Float),
        ("orderdate", FieldType::Int),
    ])
}

pub const O_ORDERKEY: usize = 0;
pub const O_CUSTKEY: usize = 1;
pub const O_ORDERSTATUS: usize = 2;
pub const O_TOTALPRICE: usize = 3;
pub const O_ORDERDATE: usize = 4;

/// customer: (custkey, mktsegment).
pub fn customer_schema() -> Schema {
    Schema::new(&[
        ("custkey", FieldType::Int),
        ("mktsegment", FieldType::Str),
    ])
}

pub const C_CUSTKEY: usize = 0;

const RETURN_FLAGS: &[&str] = &["A", "N", "R"];
const LINE_STATUS: &[&str] = &["O", "F"];
const ORDER_STATUS: &[&str] = &["O", "F", "P"];
const SEGMENTS: &[&str] = &["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"];

#[inline]
fn gaussian(rng: &mut Rng) -> f64 {
    // Box-Muller.
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Bell-shaped totalprice like Fig. 3.15b: mean ~150k, sd ~60k, clipped
/// to [1000, 550_000].
pub fn sample_totalprice(rng: &mut Rng) -> f64 {
    let v = 150_000.0 + 60_000.0 * gaussian(rng);
    v.clamp(1_000.0, 550_000.0)
}

macro_rules! make_source {
    ($name:ident, $per_sf:expr, $make:expr) => {
        /// Deterministic partitioned generator; see module docs.
        pub struct $name {
            total: usize,
            parts: usize,
            idx: usize,
            pos: usize,
            seed: u64,
        }

        impl $name {
            /// `sf` scale-factor units; partition `idx` of `parts`.
            pub fn new(sf: f64, parts: usize, idx: usize, seed: u64) -> $name {
                $name {
                    total: (sf * $per_sf as f64) as usize,
                    parts,
                    idx,
                    pos: 0,
                    seed,
                }
            }

            pub fn with_rows(total: usize, parts: usize, idx: usize, seed: u64) -> $name {
                $name { total, parts, idx, pos: 0, seed }
            }
        }

        impl TupleSource for $name {
            fn next_tuple(&mut self) -> Option<Tuple> {
                let i = self.idx + self.pos * self.parts;
                if i >= self.total {
                    return None;
                }
                self.pos += 1;
                let mut rng =
                    Rng::new(self.seed ^ (i as u64).wrapping_mul(0xD1B54A32D192ED03));
                #[allow(clippy::redundant_closure_call)]
                Some(($make)(i, &mut rng))
            }

            fn reset(&mut self) {
                self.pos = 0;
            }

            fn position(&self) -> usize {
                self.pos
            }

            fn seek(&mut self, pos: usize) {
                self.pos = pos;
            }

            fn len_hint(&self) -> Option<usize> {
                let (t, p, i) = (self.total, self.parts, self.idx);
                Some(if i >= t { 0 } else { (t - i + p - 1) / p })
            }

            fn fork(&self) -> Option<Box<dyn TupleSource>> {
                Some(Box::new($name {
                    total: self.total,
                    parts: self.parts,
                    idx: self.idx,
                    pos: self.pos,
                    seed: self.seed,
                }))
            }

            fn split(&mut self, n: usize) -> Option<Vec<Box<dyn TupleSource>>> {
                assert!(n > 0);
                // Stride re-cut of the unread remainder; each tuple is a
                // pure function of its global id, so replay is stable.
                Some(
                    (0..n)
                        .map(|j| {
                            Box::new($name {
                                total: self.total,
                                parts: self.parts * n,
                                idx: self.idx + (self.pos + j) * self.parts,
                                pos: 0,
                                seed: self.seed,
                            }) as Box<dyn TupleSource>
                        })
                        .collect(),
                )
            }
        }
    };
}

make_source!(LineitemSource, LINEITEM_PER_SF, |i: usize, rng: &mut Rng| {
    Tuple::new(vec![
        Value::Int((i / 4) as i64),
        Value::Int(1 + rng.below(50) as i64),
        Value::Float(1_000.0 + rng.f64() * 90_000.0),
        Value::Float((rng.below(11) as f64) / 100.0),
        Value::Float((rng.below(9) as f64) / 100.0),
        Value::Str(Arc::from(*rng.pick(RETURN_FLAGS))),
        Value::Str(Arc::from(*rng.pick(LINE_STATUS))),
        Value::Int(rng.range_i64(19920101, 19981201)),
    ])
});

make_source!(OrdersSource, ORDERS_PER_SF, |i: usize, rng: &mut Rng| {
    let custkeys = (self_customers(i) as u64).max(1);
    Tuple::new(vec![
        Value::Int(i as i64),
        Value::Int(rng.below(custkeys) as i64),
        Value::Str(Arc::from(*rng.pick(ORDER_STATUS))),
        Value::Float(sample_totalprice(rng)),
        Value::Int(rng.range_i64(19920101, 19981201)),
    ])
});

/// custkey domain used by [`OrdersSource`]; sized so Q13-style group-bys
/// have realistic group counts. (Free function because the macro
/// closure cannot capture the source struct.)
fn self_customers(_i: usize) -> usize {
    10_000
}

make_source!(CustomerSource, CUSTOMER_PER_SF, |i: usize, rng: &mut Rng| {
    Tuple::new(vec![
        Value::Int(i as i64),
        Value::Str(Arc::from(*rng.pick(SEGMENTS))),
    ])
});

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineitem_row_count_scales() {
        let mut s = LineitemSource::new(0.1, 1, 0, 1);
        let mut n = 0;
        while s.next_tuple().is_some() {
            n += 1;
        }
        assert_eq!(n, (0.1 * LINEITEM_PER_SF as f64) as usize);
    }

    #[test]
    fn totalprice_bell_shaped() {
        // More mass in the middle band than the outer bands → equal-width
        // range partitioning is skewed (the premise of W3, Table 3.2).
        let mut rng = Rng::new(3);
        let (mut mid, mut outer) = (0, 0);
        for _ in 0..20_000 {
            let p = sample_totalprice(&mut rng);
            if (90_000.0..210_000.0).contains(&p) {
                mid += 1;
            } else {
                outer += 1;
            }
        }
        assert!(mid > outer * 2, "mid={mid} outer={outer}");
    }

    #[test]
    fn orders_custkeys_in_domain() {
        let mut s = OrdersSource::new(0.2, 1, 0, 5);
        while let Some(t) = s.next_tuple() {
            let ck = t.get(O_CUSTKEY).as_int().unwrap();
            assert!((0..10_000).contains(&ck));
        }
    }

    #[test]
    fn sources_replay_identically() {
        let mut s = LineitemSource::new(0.05, 3, 2, 9);
        let a: Vec<Tuple> = std::iter::from_fn(|| s.next_tuple()).collect();
        s.reset();
        let b: Vec<Tuple> = std::iter::from_fn(|| s.next_tuple()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn partition_union_is_total() {
        let total: usize = (0..5)
            .map(|p| {
                let s = CustomerSource::new(1.0, 5, p, 2);
                s.len_hint().unwrap()
            })
            .sum();
        assert_eq!(total, CUSTOMER_PER_SF);
    }
}

//! Small self-contained utilities: deterministic RNG, a mini
//! property-testing harness, and CLI argument parsing.
//!
//! The offline crate set has no `rand`, `proptest`, or `clap`; these
//! modules provide the minimal equivalents the rest of the crate needs.

pub mod rng;
pub mod check;
pub mod cli;

pub use rng::{Rng, Zipf};

//! `check` — a minimal property-based testing harness (proptest-lite).
//!
//! The vendored crate set has no `proptest`/`quickcheck`, so coordinator
//! invariants (routing, batching, state migration, region graphs) are
//! property-tested with this ~100-line harness: generate N random cases
//! from a seeded [`Rng`](crate::util::Rng), run the property, and on
//! failure greedily shrink the case before reporting.

use super::rng::Rng;

/// Number of random cases per property (override with `CHECK_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator produces a value from randomness, and knows how to shrink
/// a failing value toward smaller counterexamples.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values, most aggressive first. Default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` against `cases` random values from `gen`; panic with the
/// (shrunk) counterexample on failure. Deterministic per `seed`.
pub fn check<G: Gen>(seed: u64, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    check_n(seed, default_cases(), gen, prop)
}

/// Like [`check`] with an explicit case count.
pub fn check_n<G: Gen>(
    seed: u64,
    cases: u32,
    gen: &G,
    prop: impl Fn(&G::Value) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let shrunk = shrink_loop(gen, v, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); \
                 shrunk counterexample: {shrunk:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut v: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy: take the first shrink candidate that still fails; stop when
    // no candidate fails (local minimum) or after a bounded number of steps.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    v
}

/// Generator for `u64` in `[lo, hi]`; shrinks toward `lo`.
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator for vectors of values from an inner generator; shrinks by
/// halving the vector and by shrinking individual elements.
pub struct VecGen<G> {
    pub inner: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.below(self.max_len as u64 + 1) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
            let mut tail = v.clone();
            tail.pop();
            out.push(tail);
            // Shrink the first element.
            for cand in self.inner.shrink(&v[0]) {
                let mut w = v.clone();
                w[0] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Generator that maps another generator through a function (no shrink).
pub struct MapGen<G, F> {
    pub inner: G,
    pub f: F,
}

impl<G: Gen, T: Clone + std::fmt::Debug, F: Fn(G::Value) -> T> Gen for MapGen<G, F> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_clean() {
        check(1, &U64Range(0, 100), |v| *v <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, &U64Range(0, 100), |v| *v < 5);
    }

    #[test]
    fn shrinks_to_minimal() {
        // Collect the shrunk value by catching the panic message.
        let r = std::panic::catch_unwind(|| {
            check_n(3, 200, &U64Range(0, 1000), |v| *v < 50);
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on a small counterexample (>= 50).
        let shrunk: u64 = msg
            .rsplit(": ")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(shrunk >= 50, "shrunk {shrunk} not a counterexample");
        assert!(shrunk <= 75, "shrunk {shrunk} far from minimal");
    }

    #[test]
    fn vec_gen_respects_max_len() {
        let g = VecGen { inner: U64Range(0, 9), max_len: 8 };
        check(4, &g, |v| v.len() <= 8 && v.iter().all(|x| *x <= 9));
    }
}

//! Tiny CLI argument parser (the vendored crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, which is all the `amber` launcher and the bench harnesses
//! need.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Get an option parsed to `T`, or a default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Get a required string option.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Whether a bare flag is present.
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("run --workers 8 w1 --batch=400");
        assert_eq!(a.positional, vec!["run", "w1"]);
        assert_eq!(a.get::<usize>("workers", 0), 8);
        assert_eq!(a.get::<usize>("batch", 0), 400);
    }

    #[test]
    fn flags_detected() {
        let a = parse("bench --verbose --workers 2");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get::<usize>("workers", 0), 2);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("run --checkpoint");
        assert!(a.has("checkpoint"));
        assert!(a.positional == vec!["run"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get::<u64>("tau", 100), 100);
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("x --lo -5");
        // "-5" doesn't start with --, so it is consumed as the value.
        assert_eq!(a.get::<i64>("lo", 0), -5);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! All experiment workloads are generated from seeded [`Rng`] instances so
//! every run — and every fault-tolerance replay (engine assumption A3,
//! §2.6.2 of the paper) — is bit-reproducible.
//!
//! Implementation: `splitmix64` for seeding, `xoshiro256**` for the
//! stream (public-domain algorithms by Blackman & Vigna).

/// A small, fast, deterministic RNG (xoshiro256**, seeded via splitmix64).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG with an independent stream (for per-worker seeds).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// A sampler for the Zipf distribution over `{0, .., n-1}` with exponent
/// `theta`; used by the DSB-like and synthetic skewed workloads
/// (Fig. 3.15 of the paper shows the empirical key distributions it
/// mimics).
///
/// Exact inverse-CDF sampling: the full CDF is precomputed (our key
/// domains are ≤ a few thousand) and each draw is one binary search.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Create a Zipf sampler over `n` items with skew exponent `theta`
    /// (`theta = 0` is uniform; ~1.0 is heavily skewed).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n as usize);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += (k as f64 + 1.0).powf(-theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one sample; returns a rank in `[0, n)` where rank 0 is the
    /// most frequent key.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        // Binary search for the first cdf entry ≥ u.
        let mut lo = 0usize;
        let mut hi = self.cdf.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let z = Zipf::new(100, 1.0);
        let mut r = Rng::new(17);
        let mut counts = vec![0u32; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        let max_idx = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap()
            .0;
        assert_eq!(max_idx, 0);
        // Rank 0 should dominate rank 10 clearly at theta=1.
        assert!(counts[0] > counts[10] * 3);
    }

    #[test]
    fn zipf_theta_zero_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut r = Rng::new(23);
        let mut counts = vec![0u32; 10];
        for _ in 0..50_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        for &c in &counts {
            assert!((4_000..6_000).contains(&c));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}

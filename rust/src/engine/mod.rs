//! The Amber engine (Ch. 2): a parallel, pipelined dataflow engine with
//! a fast control-message path.
//!
//! An input workflow is a DAG of physical operators ([`dag::Workflow`]).
//! Each operator is translated to `n` **worker** actors (OS threads with
//! mailboxes); a **coordinator** (the paper's controller + principal
//! actors, colocated per fault-tolerance assumption A1) deploys the
//! actor DAG, routes control messages, evaluates global breakpoints, and
//! drives Reshape and Maestro.
//!
//! Message model (§2.3.3 / §2.4.2): data flows in batched
//! [`message::DataEvent`]s over bounded FIFO channels (congestion
//! control); payloads are shared [`crate::tuple::TupleBatch`]es, so
//! fan-out edges (broadcast, replication) clone an `Arc`, not tuples.
//! Control flows through a separate always-responsive
//! [`channel::ControlInbox`] whose `pending` flag the worker's
//! data-processing loop checks **between chunks** of at most
//! `ctrl_check_interval` tuples — the paper's per-iteration
//! `Paused`-variable check (interval 1 is exactly that) generalized to
//! amortize per-tuple overheads while keeping pause latency sub-second
//! regardless of batch size.
//!
//! Worker sets are **universally elastic**: the [`scale`] module
//! changes any operator's parallelism mid-run inside one fenced epoch
//! (pause → extract/re-hash state → rewire partitioners → resume) —
//! including sources (splittable scan ranges), scatter-merge operators
//! (epoch-keyed EOF peer barrier) and broadcast-input operators
//! (build-side replication) — driven manually
//! ([`Execution::scale_operator`]) or by the
//! [`scale::AutoscalePlugin`] policy (with an ownership guard so the
//! plugin and an external scheduler never fight over one operator).
//! The [`migrate`] module generalizes that fence into **live plan
//! migration**: repartitioning a live edge, splicing a
//! materialization in or out, or applying a multi-operator worker
//! re-plan — each as an ordered sequence of fenced steps with
//! abort-and-restore ([`Execution::migrate`]).
//!
//! Execution is **supervised** (§2.6 closed into a loop): worker
//! threads run under panic containment (`catch_unwind` →
//! [`message::WorkerEvent::WorkerFailed`]), stamp a heartbeat the
//! coordinator sweeps on its timer
//! ([`crate::config::Config::heartbeat_timeout_ms`]), and on a
//! declared failure — crash or stall — the coordinator restores the
//! latest automatic checkpoint
//! ([`crate::config::Config::checkpoint_interval_ms`]), re-injects the
//! control-replay log (§2.6.2) and resumes, with bounded exponential
//! retries escalating to a structured [`fault::ExecError`].
//! Deterministic failures are injected through a seeded
//! [`fault::FaultPlan`].
//!
//! One `Execution` runs one workflow. Serving **many** workflows on a
//! shared worker budget is layered above, in [`crate::service`]: the
//! `EngineService` runs each admitted job as its own `Execution` (own
//! coordinator, workers and channels — the isolation boundary),
//! observes completion through [`Execution::on_done`], and drives
//! preemption with the same fenced primitives exposed here
//! (`scale_operator` to shrink a batch job, `pause`/`resume` to park
//! it while releasing its budget grant).

pub mod message;
pub mod channel;
pub mod partitioner;
pub mod operator;
pub mod dag;
pub mod worker;
pub mod breakpoint;
pub mod controller;
pub mod fault;
pub mod migrate;
pub mod scale;
pub mod spill;

pub use controller::{Execution, ExecSummary};
pub use fault::{ExecError, Fault, FaultKind, FaultPlan};
pub use migrate::{MigrationOutcome, PlanDelta};
pub use scale::AutoscalePlugin;
pub use dag::{Edge, OpSpec, Workflow};
pub use message::{ControlMessage, DataEvent, WorkerEvent, WorkerId};
pub use operator::{Emitter, OpState, Operator};
pub use partitioner::{MitigationRoute, PartitionScheme, ShareMode};
pub use spill::{MemLease, MemoryBudget, SpillCtx, SpillFile, SpillReader, SpillSlot};

//! Fault tolerance (§2.6): quiesced checkpoints plus the
//! **control-replay log**.
//!
//! The paper's technique: (1) checkpoint operator states, and (2) log
//! every control message together with its arrival position relative to
//! the data stream — the sequence number of the data message being
//! processed and the index of the last processed tuple within it
//! (`⟨Pause, '8', (6, 34)⟩` in Fig. 2.6). Recovery reruns the
//! deterministic computation from the checkpoint (assumption A3) and
//! re-injects the logged control messages at exactly their recorded
//! positions, so the user-visible post-control states (e.g. "paused at
//! tuple 34 of message 6") are reproduced bit-for-bit.
//!
//! Our engine takes *quiesced* checkpoints (pause-all → snapshot →
//! resume), so the replay log only needs to cover control messages
//! received after the latest checkpoint.

use crate::engine::message::{ControlMessage, DataEvent, WorkerId};
use std::collections::HashMap;

/// Position in a worker's deterministic data stream: (number of data
/// messages dequeued so far, tuple index within the current batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ReplayPos {
    pub msg_count: u64,
    pub tuple_idx: usize,
}

/// One control-replay log record (§2.6.2): the control message and the
/// DP position at which its effect was applied.
#[derive(Clone, Debug)]
pub struct LogRecord {
    pub worker: WorkerId,
    pub ctrl: ControlMessage,
    pub pos: ReplayPos,
}

/// Snapshot of a single worker taken while the workflow is paused.
#[derive(Default)]
pub struct WorkerSnapshot {
    /// Operator keyed state.
    pub op_state: crate::engine::operator::OpState,
    /// Unprocessed input: stashed events plus the remainder of the
    /// partially-processed batch (resumption-index semantics, §2.4.3).
    pub pending: Vec<DataEvent>,
    /// Source read position (scan workers replay from here).
    pub source_pos: Option<usize>,
    /// A fork of the scan worker's live source at its read position
    /// ([`crate::workloads::TupleSource::fork`]). After an elastic
    /// *source* scale the live scan ranges no longer correspond to any
    /// plan-time partitioning, so `source_pos` alone cannot reproduce
    /// them; recovery installs this fork instead when present, which is
    /// how a checkpoint taken across a source-scale epoch re-deploys at
    /// the post-scale parallelism. `None` for non-source workers and
    /// for sources that do not implement `fork` (those fall back to
    /// the plan-time builder + `source_pos`).
    pub source: Option<Box<dyn crate::workloads::TupleSource>>,
    /// EOFs already seen per port.
    pub eofs_seen: Vec<usize>,
    /// Data messages dequeued so far (replay-position base). When the
    /// snapshot was taken mid-batch this counts the interrupted batch
    /// as *not yet dequeued* (its remainder is the first pending
    /// event), so the recovered stream numbering matches the original.
    pub msg_count: u64,
    /// Tuple offset of the interrupted batch's remainder: recovered
    /// index `i` within that batch corresponds to original index
    /// `i + resume_offset` (Fig. 2.6's "(6, 34)" alignment).
    pub resume_offset: usize,
    /// Stats counters to restore (processed/produced).
    pub processed: u64,
    pub produced: u64,
}

// Manual: the embedded `Box<dyn TupleSource>` has no `Debug`.
impl std::fmt::Debug for WorkerSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerSnapshot")
            .field("op_state", &self.op_state)
            .field("pending", &self.pending)
            .field("source_pos", &self.source_pos)
            .field("source", &self.source.as_ref().map(|_| "<fork>"))
            .field("eofs_seen", &self.eofs_seen)
            .field("msg_count", &self.msg_count)
            .field("resume_offset", &self.resume_offset)
            .field("processed", &self.processed)
            .field("produced", &self.produced)
            .finish()
    }
}

/// A whole-workflow checkpoint: one snapshot per worker.
#[derive(Debug, Default)]
pub struct Checkpoint {
    pub workers: HashMap<WorkerId, WorkerSnapshot>,
}

impl Checkpoint {
    pub fn total_state_tuples(&self) -> usize {
        self.workers
            .values()
            .map(|s| s.op_state.size_tuples())
            .sum()
    }
}

/// The coordinator-side control-replay log: records per worker, in
/// arrival order, since the last checkpoint.
#[derive(Debug, Default)]
pub struct ReplayLog {
    records: HashMap<WorkerId, Vec<LogRecord>>,
}

impl ReplayLog {
    pub fn append(&mut self, rec: LogRecord) {
        self.records.entry(rec.worker).or_default().push(rec);
    }

    /// Records for one worker (recovery sends these via
    /// `ControlMessage::ReplayLog`).
    pub fn for_worker(&self, w: WorkerId) -> Vec<LogRecord> {
        self.records.get(&w).cloned().unwrap_or_default()
    }

    /// Clear after a new checkpoint (its effects are now in state).
    pub fn clear(&mut self) {
        self.records.clear();
    }

    pub fn len(&self) -> usize {
        self.records.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_log_per_worker_order() {
        let mut log = ReplayLog::default();
        let w = WorkerId::new(1, 0);
        for i in 0..3 {
            log.append(LogRecord {
                worker: w,
                ctrl: ControlMessage::Pause,
                pos: ReplayPos { msg_count: i, tuple_idx: 0 },
            });
        }
        let recs = log.for_worker(w);
        assert_eq!(recs.len(), 3);
        assert!(recs.windows(2).all(|p| p[0].pos <= p[1].pos));
        assert_eq!(log.for_worker(WorkerId::new(9, 9)).len(), 0);
        log.clear();
        assert!(log.is_empty());
    }

    #[test]
    fn replay_pos_ordering() {
        let a = ReplayPos { msg_count: 6, tuple_idx: 34 };
        let b = ReplayPos { msg_count: 6, tuple_idx: 35 };
        let c = ReplayPos { msg_count: 7, tuple_idx: 0 };
        assert!(a < b && b < c);
    }
}

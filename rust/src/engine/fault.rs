//! Fault tolerance (§2.6): quiesced checkpoints plus the
//! **control-replay log**.
//!
//! The paper's technique: (1) checkpoint operator states, and (2) log
//! every control message together with its arrival position relative to
//! the data stream — the sequence number of the data message being
//! processed and the index of the last processed tuple within it
//! (`⟨Pause, '8', (6, 34)⟩` in Fig. 2.6). Recovery reruns the
//! deterministic computation from the checkpoint (assumption A3) and
//! re-injects the logged control messages at exactly their recorded
//! positions, so the user-visible post-control states (e.g. "paused at
//! tuple 34 of message 6") are reproduced bit-for-bit.
//!
//! Our engine takes *quiesced* checkpoints (pause-all → snapshot →
//! resume), so the replay log only needs to cover control messages
//! received after the latest checkpoint.

use crate::engine::message::{ControlMessage, DataEvent, WorkerId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Position in a worker's deterministic data stream: (number of data
/// messages dequeued so far, tuple index within the current batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ReplayPos {
    pub msg_count: u64,
    pub tuple_idx: usize,
}

/// One control-replay log record (§2.6.2): the control message and the
/// DP position at which its effect was applied.
#[derive(Clone, Debug)]
pub struct LogRecord {
    pub worker: WorkerId,
    pub ctrl: ControlMessage,
    pub pos: ReplayPos,
}

/// Snapshot of a single worker taken while the workflow is paused.
#[derive(Default)]
pub struct WorkerSnapshot {
    /// Operator keyed state.
    pub op_state: crate::engine::operator::OpState,
    /// Unprocessed input: stashed events plus the remainder of the
    /// partially-processed batch (resumption-index semantics, §2.4.3).
    pub pending: Vec<DataEvent>,
    /// Source read position (scan workers replay from here).
    pub source_pos: Option<usize>,
    /// A fork of the scan worker's live source at its read position
    /// ([`crate::workloads::TupleSource::fork`]). After an elastic
    /// *source* scale the live scan ranges no longer correspond to any
    /// plan-time partitioning, so `source_pos` alone cannot reproduce
    /// them; recovery installs this fork instead when present, which is
    /// how a checkpoint taken across a source-scale epoch re-deploys at
    /// the post-scale parallelism. `None` for non-source workers and
    /// for sources that do not implement `fork` (those fall back to
    /// the plan-time builder + `source_pos`).
    pub source: Option<Box<dyn crate::workloads::TupleSource>>,
    /// EOFs already seen per port.
    pub eofs_seen: Vec<usize>,
    /// Data messages dequeued so far (replay-position base). When the
    /// snapshot was taken mid-batch this counts the interrupted batch
    /// as *not yet dequeued* (its remainder is the first pending
    /// event), so the recovered stream numbering matches the original.
    pub msg_count: u64,
    /// Tuple offset of the interrupted batch's remainder: recovered
    /// index `i` within that batch corresponds to original index
    /// `i + resume_offset` (Fig. 2.6's "(6, 34)" alignment).
    pub resume_offset: usize,
    /// Stats counters to restore (processed/produced).
    pub processed: u64,
    pub produced: u64,
    /// Per-port closed flags at snapshot time. A port that was already
    /// closed had its `finish_port` outputs emitted (and counted
    /// downstream) before the checkpoint; the restored worker must not
    /// close it — and emit — again. Empty means "all open" (fresh or
    /// pre-supervision snapshots).
    pub ports_done: Vec<bool>,
    /// Whether the worker had fully finished at snapshot time. A
    /// restored finished worker re-announces completion to the
    /// coordinator but re-runs neither `finish` nor its EOF broadcast
    /// (downstream snapshots already account for both).
    pub finished: bool,
}

impl WorkerSnapshot {
    /// Deep copy for repeated recovery attempts: plain state clones,
    /// and the embedded live source (if any) duplicates via
    /// [`crate::workloads::TupleSource::fork`] — sources that cannot
    /// fork fall back to `source_pos` + the plan-time builder, exactly
    /// as restore itself does.
    pub fn duplicate(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            op_state: self.op_state.clone(),
            pending: self.pending.clone(),
            source_pos: self.source_pos,
            source: self.source.as_ref().and_then(|s| s.fork()),
            eofs_seen: self.eofs_seen.clone(),
            msg_count: self.msg_count,
            resume_offset: self.resume_offset,
            processed: self.processed,
            produced: self.produced,
            ports_done: self.ports_done.clone(),
            finished: self.finished,
        }
    }
}

// Manual: the embedded `Box<dyn TupleSource>` has no `Debug`.
impl std::fmt::Debug for WorkerSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerSnapshot")
            .field("op_state", &self.op_state)
            .field("pending", &self.pending)
            .field("source_pos", &self.source_pos)
            .field("source", &self.source.as_ref().map(|_| "<fork>"))
            .field("eofs_seen", &self.eofs_seen)
            .field("msg_count", &self.msg_count)
            .field("resume_offset", &self.resume_offset)
            .field("processed", &self.processed)
            .field("produced", &self.produced)
            .field("ports_done", &self.ports_done)
            .field("finished", &self.finished)
            .finish()
    }
}

/// A whole-workflow checkpoint: one snapshot per worker.
#[derive(Debug, Default)]
pub struct Checkpoint {
    pub workers: HashMap<WorkerId, WorkerSnapshot>,
}

impl Checkpoint {
    pub fn total_state_tuples(&self) -> usize {
        self.workers
            .values()
            .map(|s| s.op_state.size_tuples())
            .sum()
    }

    /// Deep copy (see [`WorkerSnapshot::duplicate`]) so the coordinator
    /// can retain one restore point across several recovery attempts —
    /// each attempt consumes per-worker snapshots by value.
    pub fn duplicate(&self) -> Checkpoint {
        Checkpoint {
            workers: self
                .workers
                .iter()
                .map(|(id, s)| (*id, s.duplicate()))
                .collect(),
        }
    }
}

/// The coordinator-side control-replay log: records per worker, in
/// arrival order, since the last checkpoint.
#[derive(Debug, Default)]
pub struct ReplayLog {
    records: HashMap<WorkerId, Vec<LogRecord>>,
}

impl ReplayLog {
    pub fn append(&mut self, rec: LogRecord) {
        self.records.entry(rec.worker).or_default().push(rec);
    }

    /// Records for one worker (recovery sends these via
    /// `ControlMessage::ReplayLog`).
    pub fn for_worker(&self, w: WorkerId) -> Vec<LogRecord> {
        self.records.get(&w).cloned().unwrap_or_default()
    }

    /// Clear after a new checkpoint (its effects are now in state).
    pub fn clear(&mut self) {
        self.records.clear();
    }

    pub fn len(&self) -> usize {
        self.records.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One kind of injectable fault. All faults are *positional* — they
/// name a worker (or outgoing edge) and a deterministic stream
/// position — so the same plan reproduces the same failure bit-for-bit
/// regardless of thread scheduling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worker `worker` panics once its processed-tuple count reaches
    /// `after_processed` (an arbitrary replay position: the check runs
    /// between chunks of the DP loop, exactly where control messages
    /// are applied).
    PanicAt { worker: WorkerId, after_processed: u64 },
    /// Worker `worker` stalls — sleeps *without* stamping its
    /// heartbeat — for `for_ms` once its processed count reaches
    /// `after_processed`. Lets tests exercise the coordinator's
    /// stall-vs-crash distinction.
    StallAt {
        worker: WorkerId,
        after_processed: u64,
        for_ms: u64,
    },
    /// Drop the `nth` (1-based) data batch `worker` sends toward
    /// operator `to_op`. Lossy by construction: downstream results
    /// will be short unless a checkpoint/recovery cycle re-produces
    /// the dropped rows.
    DropNth { worker: WorkerId, to_op: usize, nth: u64 },
    /// Delay the `nth` (1-based) data batch `worker` sends toward
    /// operator `to_op` by `for_ms`. Per-edge FIFO is preserved (the
    /// sender blocks), so results stay byte-exact.
    DelayNth {
        worker: WorkerId,
        to_op: usize,
        nth: u64,
        for_ms: u64,
    },
}

/// One injected fault with a bounded fire count.
///
/// The fire counter is shared across [`Clone`]s (an [`Arc`]), so a
/// one-shot fault stays one-shot across the worker respawns of
/// automatic recovery — and a fault constructed with
/// [`Fault::times`]`(n)` for `n > recovery_max_retries` forces the
/// retry-exhaustion path deterministically.
#[derive(Clone, Debug)]
pub struct Fault {
    pub kind: FaultKind,
    max_fires: u32,
    fired: Arc<AtomicU32>,
}

impl Fault {
    fn new(kind: FaultKind) -> Fault {
        Fault { kind, max_fires: 1, fired: Arc::new(AtomicU32::new(0)) }
    }

    /// One-shot panic of `worker` at processed-count `after_processed`.
    pub fn panic_at(worker: WorkerId, after_processed: u64) -> Fault {
        Fault::new(FaultKind::PanicAt { worker, after_processed })
    }

    /// One-shot heartbeat-silent stall of `worker` for `for_ms`.
    pub fn stall_at(worker: WorkerId, after_processed: u64, for_ms: u64) -> Fault {
        Fault::new(FaultKind::StallAt { worker, after_processed, for_ms })
    }

    /// Drop the `nth` data batch `worker` sends toward `to_op`.
    pub fn drop_nth(worker: WorkerId, to_op: usize, nth: u64) -> Fault {
        Fault::new(FaultKind::DropNth { worker, to_op, nth })
    }

    /// Delay the `nth` data batch `worker` sends toward `to_op`.
    pub fn delay_nth(worker: WorkerId, to_op: usize, nth: u64, for_ms: u64) -> Fault {
        Fault::new(FaultKind::DelayNth { worker, to_op, nth, for_ms })
    }

    /// Allow this fault to fire up to `n` times (default 1). A panic
    /// fault re-fires after recovery replays past its position again.
    pub fn times(mut self, n: u32) -> Fault {
        self.max_fires = n;
        self
    }

    /// Atomically claim one firing; `false` once `max_fires` is spent.
    pub fn try_fire(&self) -> bool {
        let mut cur = self.fired.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_fires {
                return false;
            }
            match self.fired.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// How many times this fault has fired (shared across clones).
    pub fn fires(&self) -> u32 {
        self.fired.load(Ordering::SeqCst)
    }
}

/// A deterministic fault-injection plan, threaded through
/// [`crate::config::Config::fault_plan`] into the worker DP loop and
/// the exchange send path. Chaos fuzzers build one from their seed and
/// assert byte-exact results vs the same seed without faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn push(&mut self, f: Fault) {
        self.faults.push(f);
    }

    /// Worker-scoped faults (panic/stall) targeting `w`.
    pub fn worker_faults(&self, w: WorkerId) -> Vec<Fault> {
        self.faults
            .iter()
            .filter(|f| {
                matches!(
                    f.kind,
                    FaultKind::PanicAt { worker, .. } | FaultKind::StallAt { worker, .. }
                        if worker == w
                )
            })
            .cloned()
            .collect()
    }

    /// Edge-scoped faults (drop/delay) whose sending side is `w`.
    pub fn edge_faults(&self, w: WorkerId) -> Vec<Fault> {
        self.faults
            .iter()
            .filter(|f| {
                matches!(
                    f.kind,
                    FaultKind::DropNth { worker, .. } | FaultKind::DelayNth { worker, .. }
                        if worker == w
                )
            })
            .cloned()
            .collect()
    }

    /// Total firings across all faults so far.
    pub fn total_fires(&self) -> u64 {
        self.faults.iter().map(|f| f.fires() as u64).sum()
    }
}

/// Structured failure surfaced by supervised execution (via
/// `ExecSummary::error`): the run terminated abnormally but *cleanly*
/// — workers joined, waiters released — instead of hanging.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// A worker failed and automatic recovery is unavailable
    /// (`Config::ft_log` off, so there is no replay log to make
    /// recovery exact). The run aborted.
    Unsupervised { worker: WorkerId, cause: String },
    /// Automatic recovery was attempted `attempts` times and the
    /// workflow kept failing; the run aborted.
    RecoveryExhausted { attempts: u32, last_failure: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Unsupervised { worker, cause } => {
                write!(f, "worker {worker:?} failed without supervision: {cause}")
            }
            ExecError::RecoveryExhausted { attempts, last_failure } => {
                write!(
                    f,
                    "recovery exhausted after {attempts} attempt(s); last failure: {last_failure}"
                )
            }
        }
    }
}

impl std::error::Error for ExecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_log_per_worker_order() {
        let mut log = ReplayLog::default();
        let w = WorkerId::new(1, 0);
        for i in 0..3 {
            log.append(LogRecord {
                worker: w,
                ctrl: ControlMessage::Pause,
                pos: ReplayPos { msg_count: i, tuple_idx: 0 },
            });
        }
        let recs = log.for_worker(w);
        assert_eq!(recs.len(), 3);
        assert!(recs.windows(2).all(|p| p[0].pos <= p[1].pos));
        assert_eq!(log.for_worker(WorkerId::new(9, 9)).len(), 0);
        log.clear();
        assert!(log.is_empty());
    }

    #[test]
    fn replay_pos_ordering() {
        let a = ReplayPos { msg_count: 6, tuple_idx: 34 };
        let b = ReplayPos { msg_count: 6, tuple_idx: 35 };
        let c = ReplayPos { msg_count: 7, tuple_idx: 0 };
        assert!(a < b && b < c);
    }

    #[test]
    fn fault_fire_count_shared_across_clones() {
        let f = Fault::panic_at(WorkerId::new(1, 0), 100);
        let g = f.clone(); // a recovery respawn re-threads the same plan
        assert!(f.try_fire());
        assert!(!g.try_fire(), "one-shot fault fired twice across clones");
        assert_eq!(g.fires(), 1);
        let multi = Fault::stall_at(WorkerId::new(0, 0), 0, 5).times(3);
        assert!(multi.try_fire() && multi.try_fire() && multi.try_fire());
        assert!(!multi.try_fire());
    }

    #[test]
    fn fault_plan_filters_by_worker_and_scope() {
        let w = WorkerId::new(2, 1);
        let mut plan = FaultPlan::default();
        plan.push(Fault::panic_at(w, 64));
        plan.push(Fault::stall_at(WorkerId::new(2, 0), 10, 50));
        plan.push(Fault::delay_nth(w, 3, 2, 20));
        plan.push(Fault::drop_nth(WorkerId::new(0, 0), 1, 1));
        assert_eq!(plan.worker_faults(w).len(), 1);
        assert_eq!(plan.edge_faults(w).len(), 1);
        assert_eq!(plan.worker_faults(WorkerId::new(9, 9)).len(), 0);
        assert!(!plan.is_empty());
        assert_eq!(plan.total_fires(), 0);
    }

    #[test]
    fn exec_error_displays() {
        let e = ExecError::RecoveryExhausted { attempts: 3, last_failure: "panic: boom".into() };
        assert!(e.to_string().contains("3 attempt"));
        let u = ExecError::Unsupervised { worker: WorkerId::new(0, 0), cause: "x".into() };
        assert!(u.to_string().contains("without supervision"));
    }
}

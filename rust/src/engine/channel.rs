//! Actor mailboxes: the bounded data **ring** and the expedited
//! control inbox.
//!
//! The paper's §2.4.2 problem — a FIFO actor mailbox buries control
//! messages behind queued data — is solved there by delegating data
//! processing to a DP thread that checks a shared `Paused` flag per
//! tuple. We implement the same structure natively, with both planes
//! purpose-built for their access patterns:
//!
//! * **Data plane** — a bounded [`DataRing`] per worker. Producers
//!   (upstream workers) block when the ring is full — the paper's
//!   congestion-control backpressure (§2.3.3) — and the single
//!   consumer (the worker's DP loop) pops batches in FIFO order.
//!   Parking is Condvar-based and *lazy*: a producer signals the
//!   consumer only when the consumer has actually parked on an empty
//!   ring (and vice versa for full), so the steady-state hot path is
//!   one short critical section per message with no syscalls and no
//!   spinning. The consumer's empty-check (`try_recv` between control
//!   polls) is a single atomic load. Disconnect mirrors `std::mpsc`:
//!   a sender errors once the receiver died; the receiver reports
//!   `Disconnected` only when every sender handle has dropped *and*
//!   the ring is drained.
//! * **Control plane** — a dedicated [`ControlInbox`] with an atomic
//!   `pending` flag the DP loop reads between chunks (a single relaxed
//!   atomic load on the hot path). The inbox supports an artificial
//!   delivery delay (per-message due time) used by the Fig. 3.21
//!   control-latency experiment; messages are held in a `BinaryHeap`
//!   keyed on (due time, arrival seq), so receivers always dequeue the
//!   earliest-due message in O(log n) — a delayed message cannot
//!   head-of-line-block an already-due one behind it, and same-instant
//!   messages stay FIFO.
//!
//! The receiver's workload gauges ([`WorkerGauges`]) ride next to the
//! ring so senders maintain the queue-size/σ_w metrics without a
//! control round-trip; the per-key distribution map is written once
//! per *batch* (workers accumulate locally and merge at batch
//! boundaries), never per tuple.

use crate::engine::message::{ControlMessage, DataEvent};
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued control message: due time + arrival sequence (heap key).
struct QueuedCtrl {
    due: Instant,
    seq: u64,
    msg: ControlMessage,
}

impl PartialEq for QueuedCtrl {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for QueuedCtrl {}
impl PartialOrd for QueuedCtrl {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedCtrl {
    /// Reversed so `BinaryHeap` (a max-heap) pops the *earliest* due
    /// time first, FIFO (lowest seq) among equal due times.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.due, other.seq).cmp(&(self.due, self.seq))
    }
}

struct CtrlQueue {
    heap: BinaryHeap<QueuedCtrl>,
    next_seq: u64,
}

/// Control inbox shared between the coordinator (producer) and one
/// worker (consumer).
pub struct ControlInbox {
    queue: Mutex<CtrlQueue>,
    pending: AtomicBool,
    cv: Condvar,
}

impl Default for ControlInbox {
    fn default() -> Self {
        ControlInbox::new()
    }
}

impl ControlInbox {
    pub fn new() -> ControlInbox {
        ControlInbox {
            queue: Mutex::new(CtrlQueue { heap: BinaryHeap::new(), next_seq: 0 }),
            pending: AtomicBool::new(false),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a control message, optionally due only after `delay`
    /// (simulated delivery latency; 0 = immediate).
    pub fn send(&self, msg: ControlMessage, delay: Duration) {
        let due = Instant::now() + delay;
        let mut q = self.queue.lock().unwrap();
        let seq = q.next_seq;
        q.next_seq += 1;
        q.heap.push(QueuedCtrl { due, seq, msg });
        // The flag is best-effort: the consumer re-checks due times.
        self.pending.store(true, Ordering::Release);
        self.cv.notify_one();
    }

    /// Cheap hot-path check: is a message *possibly* ready?
    #[inline]
    pub fn maybe_pending(&self) -> bool {
        self.pending.load(Ordering::Acquire)
    }

    /// Dequeue the earliest *due* message, if any.
    pub fn try_recv(&self) -> Option<ControlMessage> {
        if !self.maybe_pending() {
            return None;
        }
        let mut q = self.queue.lock().unwrap();
        let now = Instant::now();
        if q.heap.peek().is_some_and(|item| item.due <= now) {
            let msg = q.heap.pop().unwrap().msg;
            if q.heap.is_empty() {
                self.pending.store(false, Ordering::Release);
            }
            return Some(msg);
        }
        None
    }

    /// Block until a message is due or `timeout` elapses.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<ControlMessage> {
        let deadline = Instant::now() + timeout;
        let mut q = self.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            match q.heap.peek().map(|item| item.due) {
                Some(due) if due <= now => {
                    let msg = q.heap.pop().unwrap().msg;
                    if q.heap.is_empty() {
                        self.pending.store(false, Ordering::Release);
                    }
                    return Some(msg);
                }
                Some(due) => {
                    // Wait until the earliest message becomes due (or
                    // the deadline passes).
                    if now >= deadline {
                        return None;
                    }
                    let wait = due.min(deadline).saturating_duration_since(now);
                    let (qq, _) = self
                        .cv
                        .wait_timeout(q, wait.max(Duration::from_micros(50)))
                        .unwrap();
                    q = qq;
                }
                None => {
                    if now >= deadline {
                        return None;
                    }
                    let (qq, _) = self.cv.wait_timeout(q, deadline - now).unwrap();
                    q = qq;
                }
            }
        }
    }
}

/// Shared per-worker workload gauges, readable by the coordinator
/// without a control round-trip (the paper's "controller periodically
/// collects workload metrics", §3.2.1, at 1–2% overhead, Fig. 3.25).
#[derive(Default)]
pub struct WorkerGauges {
    /// Unprocessed input tuples (senders add, the DP loop subtracts) —
    /// Reshape's default workload metric φ_w.
    pub queued: AtomicI64,
    /// Total tuples processed.
    pub processed: AtomicI64,
    /// Total tuples produced (output).
    pub produced: AtomicI64,
    /// Total tuples received, by *final* routed destination accounting:
    /// incremented by senders when routing a batch here (σ_w, the
    /// "total input received", §3.4.1) — once per destination per
    /// batch, from the routed selection-vector lengths.
    pub received: AtomicI64,
    /// Tuples this worker would have received under the *base*
    /// partitioning, ignoring mitigation overlays — the estimator's
    /// input for predicting a worker's natural future share (§3.3.2).
    pub base_received: AtomicI64,
    /// Nanoseconds spent busy (processing tuples) — the Flink-style
    /// `busyTimeMsPerSecond` metric base (§3.7.12).
    pub busy_ns: AtomicI64,
    /// Nanoseconds alive (set once the worker starts).
    pub alive_since_ns: AtomicI64,
    /// When set, the worker maintains `key_counts` (per-key workload
    /// distribution — what SBK-style mitigation needs, §3.3.1: "SBK
    /// requires the workers to store the distribution of workload per
    /// key").
    pub track_keys: AtomicBool,
    /// Input tuples seen per partitioning-key hash. Written once per
    /// batch (the worker accumulates into a thread-local map and
    /// merges at batch boundaries), so this lock is off the per-tuple
    /// hot path; readers (the Reshape plugin, baselines) take it at
    /// metric-tick cadence.
    pub key_counts: Mutex<std::collections::HashMap<u64, u64>>,
}

impl WorkerGauges {
    /// Busy fraction in [0,1] since start.
    pub fn busy_fraction(&self, now: Instant, start: Instant) -> f64 {
        let alive = now.duration_since(start).as_nanos() as f64;
        if alive <= 0.0 {
            return 0.0;
        }
        (self.busy_ns.load(Ordering::Relaxed) as f64 / alive).clamp(0.0, 1.0)
    }
}

/// Receive-side errors of the data ring (mirrors `std::mpsc`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingRecvError {
    /// Nothing queued (`try_recv`) / nothing arrived in time
    /// (`recv_timeout`).
    Empty,
    /// Every sender handle dropped and the ring is drained.
    Disconnected,
}

/// `try_send` failure: the ring was full, or the receiver died. Carries
/// the event back to the caller either way.
#[derive(Debug)]
pub enum RingTrySendError {
    Full(DataEvent),
    Disconnected(DataEvent),
}

/// Ring interior: the queue plus parking state, under one short-held
/// mutex. `rx_waiting`/`tx_waiting` make notifications lazy — nobody
/// signals a condvar unless the other side actually parked.
struct RingState {
    queue: VecDeque<DataEvent>,
    /// Receiver alive? (false once the worker's `Mailbox` dropped).
    rx_alive: bool,
    /// Consumer parked on empty.
    rx_waiting: bool,
    /// Producers parked on full.
    tx_waiting: usize,
}

/// A bounded FIFO data ring with Condvar parking (no spin on full or
/// empty): the worker's data plane. Single consumer (the owning
/// worker); producers are the upstream workers holding [`DataSender`]
/// clones. Blocking `send` on a full ring is the §2.3.3
/// congestion-control backpressure.
pub struct DataRing {
    cap: usize,
    state: Mutex<RingState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Queue-length mirror: the consumer's lock-free empty check.
    len: AtomicUsize,
    /// Live `DataSender` handles (0 + drained ⇒ disconnected).
    sender_count: AtomicUsize,
}

impl DataRing {
    /// A ring with `cap` slots and one live sender handle (the one
    /// [`mailbox`] returns).
    fn new(cap: usize) -> DataRing {
        DataRing {
            cap: cap.max(1),
            state: Mutex::new(RingState {
                queue: VecDeque::with_capacity(cap.max(1)),
                rx_alive: true,
                rx_waiting: false,
                tx_waiting: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            len: AtomicUsize::new(0),
            sender_count: AtomicUsize::new(1),
        }
    }

    fn add_sender(&self) {
        self.sender_count.fetch_add(1, Ordering::Relaxed);
    }

    fn drop_sender(&self) {
        if self.sender_count.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last sender gone: wake a parked consumer so it can
            // observe the disconnect. Taking the lock orders this
            // after any in-progress recv's park decision.
            let _s = self.state.lock().unwrap();
            self.not_empty.notify_all();
        }
    }

    fn close_rx(&self) {
        let mut s = self.state.lock().unwrap();
        s.rx_alive = false;
        // Unbuffered senders must not block forever on a dead worker.
        self.not_full.notify_all();
    }

    /// Push one event; blocks on full when `block`, else returns it.
    fn push(&self, ev: DataEvent, block: bool) -> Result<(), RingTrySendError> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.rx_alive {
                return Err(RingTrySendError::Disconnected(ev));
            }
            if s.queue.len() < self.cap {
                s.queue.push_back(ev);
                self.len.store(s.queue.len(), Ordering::Release);
                if s.rx_waiting {
                    s.rx_waiting = false;
                    self.not_empty.notify_one();
                }
                return Ok(());
            }
            if !block {
                return Err(RingTrySendError::Full(ev));
            }
            s.tx_waiting += 1;
            s = self.not_full.wait(s).unwrap();
            s.tx_waiting -= 1;
        }
    }

    /// Pop under the lock; wakes one parked producer per freed slot.
    fn pop_locked(&self, s: &mut RingState) -> Option<DataEvent> {
        let ev = s.queue.pop_front()?;
        self.len.store(s.queue.len(), Ordering::Release);
        if s.tx_waiting > 0 {
            self.not_full.notify_one();
        }
        Some(ev)
    }

    fn try_recv(&self) -> Result<DataEvent, RingRecvError> {
        // Fast path: one atomic load when idle (the DP loop polls this
        // between control checks).
        if self.len.load(Ordering::Acquire) == 0
            && self.sender_count.load(Ordering::Acquire) > 0
        {
            return Err(RingRecvError::Empty);
        }
        let mut s = self.state.lock().unwrap();
        match self.pop_locked(&mut s) {
            Some(ev) => Ok(ev),
            None if self.sender_count.load(Ordering::Acquire) == 0 => {
                Err(RingRecvError::Disconnected)
            }
            None => Err(RingRecvError::Empty),
        }
    }

    fn recv_deadline(&self, deadline: Option<Instant>) -> Result<DataEvent, RingRecvError> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(ev) = self.pop_locked(&mut s) {
                return Ok(ev);
            }
            if self.sender_count.load(Ordering::Acquire) == 0 {
                return Err(RingRecvError::Disconnected);
            }
            s.rx_waiting = true;
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        s.rx_waiting = false;
                        return Err(RingRecvError::Empty);
                    }
                    let (ss, _) = self.not_empty.wait_timeout(s, d - now).unwrap();
                    s = ss;
                }
                None => {
                    s = self.not_empty.wait(s).unwrap();
                }
            }
            s.rx_waiting = false;
        }
    }
}

/// The sending half of a worker's data plane: a handle on the
/// receiver's ring plus its gauges, so the sender maintains the
/// queue-size metric. Cloning tracks liveness (`std::mpsc`-style
/// disconnect when the last clone drops).
pub struct DataSender {
    ring: Arc<DataRing>,
    pub gauges: Arc<WorkerGauges>,
}

impl Clone for DataSender {
    fn clone(&self) -> DataSender {
        self.ring.add_sender();
        DataSender { ring: self.ring.clone(), gauges: self.gauges.clone() }
    }
}

impl Drop for DataSender {
    fn drop(&mut self) {
        self.ring.drop_sender();
    }
}

impl DataSender {
    /// Send a data event, blocking if the receiver's ring is full
    /// (congestion control / backpressure).
    pub fn send(&self, ev: DataEvent) -> Result<(), ()> {
        if let DataEvent::Batch(b) = &ev {
            self.gauges
                .queued
                .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
        }
        // Blocking send (FIFO, bounded); error only if the receiver
        // hung up (crash/teardown).
        self.ring.push(ev, true).map_err(|_| ())
    }
}

/// The receiving half of the data ring (single consumer).
pub struct RingReceiver {
    ring: Arc<DataRing>,
}

impl Drop for RingReceiver {
    fn drop(&mut self) {
        self.ring.close_rx();
    }
}

impl RingReceiver {
    /// Non-blocking pop; `Empty` costs one atomic load.
    pub fn try_recv(&self) -> Result<DataEvent, RingRecvError> {
        self.ring.try_recv()
    }

    /// Blocking pop (tests / drain loops).
    pub fn recv(&self) -> Result<DataEvent, RingRecvError> {
        self.ring.recv_deadline(None)
    }

    /// Pop, parking for at most `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<DataEvent, RingRecvError> {
        self.ring.recv_deadline(Some(Instant::now() + timeout))
    }
}

/// The receiving half: data ring + control inbox + gauges.
pub struct Mailbox {
    pub data: RingReceiver,
    pub control: Arc<ControlInbox>,
    pub gauges: Arc<WorkerGauges>,
}

/// Create the mailbox for one worker; returns the sender template.
pub fn mailbox(cap: usize) -> (DataSender, Mailbox) {
    let ring = Arc::new(DataRing::new(cap));
    let gauges = Arc::new(WorkerGauges::default());
    let control = Arc::new(ControlInbox::new());
    (
        DataSender { ring: ring.clone(), gauges: gauges.clone() },
        Mailbox { data: RingReceiver { ring }, control, gauges },
    )
}

/// Non-blocking send helper used in tests.
pub fn try_send(s: &DataSender, ev: DataEvent) -> Result<(), RingTrySendError> {
    if let DataEvent::Batch(b) = &ev {
        s.gauges
            .queued
            .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
    }
    s.ring.push(ev, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::message::{DataMessage, WorkerId};
    use crate::tuple::{Tuple, Value};

    fn batch(n: usize) -> DataEvent {
        DataEvent::Batch(DataMessage {
            from: WorkerId::new(0, 0),
            port: 0,
            seq: 0,
            batch: (0..n).map(|i| Tuple::new(vec![Value::Int(i as i64)])).collect(),
        })
    }

    #[test]
    fn control_inbox_immediate() {
        let inbox = ControlInbox::new();
        assert!(!inbox.maybe_pending());
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        assert!(inbox.maybe_pending());
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(inbox.try_recv().is_none());
    }

    #[test]
    fn control_inbox_respects_delay() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(50));
        // Not yet due.
        assert!(inbox.try_recv().is_none());
        std::thread::sleep(Duration::from_millis(60));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn control_inbox_fifo() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
    }

    #[test]
    fn control_inbox_fifo_among_equal_due_times() {
        // Same artificial delay ⇒ same due instant is possible; the
        // arrival sequence must break the tie FIFO.
        let inbox = ControlInbox::new();
        for _ in 0..5 {
            inbox.send(ControlMessage::Pause, Duration::ZERO);
            inbox.send(ControlMessage::Resume, Duration::ZERO);
        }
        for _ in 0..5 {
            assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
            assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
        }
    }

    #[test]
    fn delayed_head_does_not_block_due_message() {
        // A front message with an artificial delivery delay must not
        // hide an already-due message queued behind it.
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(250));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
        // The delayed head is still queued but not yet due.
        assert!(inbox.try_recv().is_none());
        assert!(inbox.maybe_pending());
        std::thread::sleep(Duration::from_millis(260));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn recv_timeout_skips_delayed_head() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_secs(60));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let t0 = Instant::now();
        let got = inbox.recv_timeout(Duration::from_secs(5));
        assert!(matches!(got, Some(ControlMessage::Resume)));
        assert!(t0.elapsed() < Duration::from_secs(1), "blocked on delayed head");
    }

    #[test]
    fn recv_timeout_wakes_on_send() {
        let inbox = Arc::new(ControlInbox::new());
        let i2 = inbox.clone();
        let h = std::thread::spawn(move || i2.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let got = h.join().unwrap();
        assert!(matches!(got, Some(ControlMessage::Resume)));
    }

    #[test]
    fn recv_timeout_times_out() {
        let inbox = ControlInbox::new();
        let t0 = Instant::now();
        assert!(inbox.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn gauges_track_queue_size() {
        let (tx, mb) = mailbox(8);
        tx.send(batch(5)).unwrap();
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 5);
        // Receiver drains and decrements per batch (done by worker
        // loop; simulate here).
        if let Ok(DataEvent::Batch(b)) = mb.data.try_recv() {
            mb.gauges
                .queued
                .fetch_sub(b.batch.len() as i64, Ordering::Relaxed);
        }
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn data_ring_fifo_per_sender() {
        let (tx, mb) = mailbox(16);
        for seq in 0..5u64 {
            tx.send(DataEvent::Batch(DataMessage {
                from: WorkerId::new(0, 0),
                port: 0,
                seq,
                batch: crate::tuple::TupleBatch::empty(),
            }))
            .unwrap();
        }
        for seq in 0..5u64 {
            match mb.data.recv().unwrap() {
                DataEvent::Batch(b) => assert_eq!(b.seq, seq),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn data_ring_backpressure_blocks_until_pop() {
        let (tx, mb) = mailbox(2);
        tx.send(batch(1)).unwrap();
        tx.send(batch(1)).unwrap();
        // Full: try_send bounces; a blocking send parks until a pop
        // frees a slot (join would hang forever if the parked sender
        // were never woken).
        assert!(matches!(try_send(&tx, batch(1)), Err(RingTrySendError::Full(_))));
        let t2 = tx.clone();
        let h = std::thread::spawn(move || t2.send(batch(1)).unwrap());
        std::thread::sleep(Duration::from_millis(40));
        mb.data.recv().unwrap(); // frees one slot
        h.join().unwrap();
        // Both remaining events drain.
        assert!(mb.data.recv().is_ok());
        assert!(mb.data.recv().is_ok());
    }

    #[test]
    fn data_ring_disconnects_when_all_senders_drop() {
        let (tx, mb) = mailbox(4);
        let tx2 = tx.clone();
        tx.send(batch(1)).unwrap();
        drop(tx);
        // A live clone keeps the ring connected.
        assert!(matches!(mb.data.try_recv(), Ok(_)));
        assert!(matches!(mb.data.try_recv(), Err(RingRecvError::Empty)));
        drop(tx2);
        assert!(matches!(
            mb.data.recv_timeout(Duration::from_secs(1)),
            Err(RingRecvError::Disconnected)
        ));
    }

    #[test]
    fn data_ring_send_errors_after_receiver_drop() {
        let (tx, mb) = mailbox(4);
        drop(mb);
        assert!(tx.send(batch(1)).is_err());
    }

    #[test]
    fn data_ring_recv_timeout_wakes_on_send() {
        let (tx, mb) = mailbox(4);
        let h = std::thread::spawn(move || mb.data.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        tx.send(batch(1)).unwrap();
        assert!(h.join().unwrap().is_ok());
    }
}

//! Actor mailboxes: the bounded data **ring** and the expedited
//! control inbox.
//!
//! The paper's §2.4.2 problem — a FIFO actor mailbox buries control
//! messages behind queued data — is solved there by delegating data
//! processing to a DP thread that checks a shared `Paused` flag per
//! tuple. We implement the same structure natively, with both planes
//! purpose-built for their access patterns:
//!
//! * **Data plane** — a bounded [`DataRing`] per worker, organized as
//!   true **per-sender SPSC lanes**: every [`DataSender`] clone owns a
//!   private bounded FIFO lane into the receiver, so concurrent
//!   producers never contend on a shared queue mutex — a sender's push
//!   touches only its own lane (one uncontended lock) plus two atomic
//!   counters. The single consumer (the worker's DP loop) drains the
//!   lanes round-robin, which preserves the only ordering the engine
//!   ever relied on: FIFO **per sender** (seq numbers, EOF/marker
//!   alignment and state-transfer ordering are all per-sender
//!   protocols; cross-sender interleaving was always scheduler-
//!   dependent). Each lane is bounded at the ring's `cap`, so a
//!   producer still blocks when *its* lane is full — the paper's
//!   congestion-control backpressure (§2.3.3), now applied to the
//!   congesting sender instead of serializing all of them. Parking is
//!   Condvar-based and *lazy* on a shared wakeup lock: a producer
//!   takes it only when the consumer has actually parked on an empty
//!   ring (and vice versa for full), so the steady-state hot path has
//!   no syscalls and no spinning. The consumer's empty-check
//!   (`try_recv` between control polls) is a single atomic load on the
//!   ring-wide length. Disconnect mirrors `std::mpsc`: a sender errors
//!   once the receiver died; the receiver reports `Disconnected` only
//!   when every sender handle has dropped *and* every lane is drained
//!   (a dropped sender's undrained lane remains poppable).
//! * **Control plane** — a dedicated [`ControlInbox`] with an atomic
//!   `pending` flag the DP loop reads between chunks (a single relaxed
//!   atomic load on the hot path). The inbox supports an artificial
//!   delivery delay (per-message due time) used by the Fig. 3.21
//!   control-latency experiment; messages are held in a `BinaryHeap`
//!   keyed on (due time, arrival seq), so receivers always dequeue the
//!   earliest-due message in O(log n) — a delayed message cannot
//!   head-of-line-block an already-due one behind it, and same-instant
//!   messages stay FIFO.
//!
//! The receiver's workload gauges ([`WorkerGauges`]) ride next to the
//! ring so senders maintain the queue-size/σ_w metrics without a
//! control round-trip; the per-key distribution map is written once
//! per *batch* (workers accumulate locally and merge at batch
//! boundaries), never per tuple.

use crate::engine::message::{ControlMessage, DataEvent};
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard from a poisoned lock.
///
/// A worker that panics is contained by the supervision layer
/// (`catch_unwind` → `WorkerFailed`), but its unwind poisons any mutex
/// it held. Every mutex in this module guards a structure that stays
/// well-formed across an unwind (pushes/pops are single complete
/// steps), so peers recover the guard and keep operating — one
/// panicking worker must degrade to a disconnect, never cascade-panic
/// the actors that share its channels.
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A queued control message: due time + arrival sequence (heap key).
struct QueuedCtrl {
    due: Instant,
    seq: u64,
    msg: ControlMessage,
}

impl PartialEq for QueuedCtrl {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for QueuedCtrl {}
impl PartialOrd for QueuedCtrl {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedCtrl {
    /// Reversed so `BinaryHeap` (a max-heap) pops the *earliest* due
    /// time first, FIFO (lowest seq) among equal due times.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.due, other.seq).cmp(&(self.due, self.seq))
    }
}

struct CtrlQueue {
    heap: BinaryHeap<QueuedCtrl>,
    next_seq: u64,
}

/// Control inbox shared between the coordinator (producer) and one
/// worker (consumer).
pub struct ControlInbox {
    queue: Mutex<CtrlQueue>,
    pending: AtomicBool,
    cv: Condvar,
}

impl Default for ControlInbox {
    fn default() -> Self {
        ControlInbox::new()
    }
}

impl ControlInbox {
    pub fn new() -> ControlInbox {
        ControlInbox {
            queue: Mutex::new(CtrlQueue { heap: BinaryHeap::new(), next_seq: 0 }),
            pending: AtomicBool::new(false),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a control message, optionally due only after `delay`
    /// (simulated delivery latency; 0 = immediate).
    pub fn send(&self, msg: ControlMessage, delay: Duration) {
        let due = Instant::now() + delay;
        let mut q = lock_ok(&self.queue);
        let seq = q.next_seq;
        q.next_seq += 1;
        q.heap.push(QueuedCtrl { due, seq, msg });
        // The flag is best-effort: the consumer re-checks due times.
        self.pending.store(true, Ordering::Release);
        self.cv.notify_one();
    }

    /// Cheap hot-path check: is a message *possibly* ready?
    #[inline]
    pub fn maybe_pending(&self) -> bool {
        self.pending.load(Ordering::Acquire)
    }

    /// Dequeue the earliest *due* message, if any.
    pub fn try_recv(&self) -> Option<ControlMessage> {
        if !self.maybe_pending() {
            return None;
        }
        let mut q = lock_ok(&self.queue);
        let now = Instant::now();
        if q.heap.peek().is_some_and(|item| item.due <= now) {
            let msg = q.heap.pop().unwrap().msg;
            if q.heap.is_empty() {
                self.pending.store(false, Ordering::Release);
            }
            return Some(msg);
        }
        None
    }

    /// Block until a message is due or `timeout` elapses.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<ControlMessage> {
        let deadline = Instant::now() + timeout;
        let mut q = lock_ok(&self.queue);
        loop {
            let now = Instant::now();
            match q.heap.peek().map(|item| item.due) {
                Some(due) if due <= now => {
                    let msg = q.heap.pop().unwrap().msg;
                    if q.heap.is_empty() {
                        self.pending.store(false, Ordering::Release);
                    }
                    return Some(msg);
                }
                Some(due) => {
                    // Wait until the earliest message becomes due (or
                    // the deadline passes).
                    if now >= deadline {
                        return None;
                    }
                    let wait = due.min(deadline).saturating_duration_since(now);
                    let (qq, _) = self
                        .cv
                        .wait_timeout(q, wait.max(Duration::from_micros(50)))
                        .unwrap_or_else(|e| e.into_inner());
                    q = qq;
                }
                None => {
                    if now >= deadline {
                        return None;
                    }
                    let (qq, _) = self
                        .cv
                        .wait_timeout(q, deadline - now)
                        .unwrap_or_else(|e| e.into_inner());
                    q = qq;
                }
            }
        }
    }
}

/// Shared per-worker workload gauges, readable by the coordinator
/// without a control round-trip (the paper's "controller periodically
/// collects workload metrics", §3.2.1, at 1–2% overhead, Fig. 3.25).
#[derive(Default)]
pub struct WorkerGauges {
    /// Unprocessed input tuples (senders add, the DP loop subtracts) —
    /// Reshape's default workload metric φ_w.
    pub queued: AtomicI64,
    /// Total tuples processed.
    pub processed: AtomicI64,
    /// Total tuples produced (output).
    pub produced: AtomicI64,
    /// Total tuples received, by *final* routed destination accounting:
    /// incremented by senders when routing a batch here (σ_w, the
    /// "total input received", §3.4.1) — once per destination per
    /// batch, from the routed selection-vector lengths.
    pub received: AtomicI64,
    /// Tuples this worker would have received under the *base*
    /// partitioning, ignoring mitigation overlays — the estimator's
    /// input for predicting a worker's natural future share (§3.3.2).
    pub base_received: AtomicI64,
    /// Nanoseconds spent busy (processing tuples) — the Flink-style
    /// `busyTimeMsPerSecond` metric base (§3.7.12).
    pub busy_ns: AtomicI64,
    /// Nanoseconds alive (set once the worker starts).
    pub alive_since_ns: AtomicI64,
    /// Liveness heartbeat: an epoch counter the worker bumps at the
    /// top of its DP loop, between processed chunks, and while parked
    /// (paused/finished/idle waits all cycle back within ~20 ms). The
    /// coordinator's supervision sweep reads it lock-free and declares
    /// the worker *stalled* after
    /// [`crate::config::Config::heartbeat_timeout_ms`] without a
    /// change — distinguishing a silent stall from a crash, which
    /// reports eagerly via
    /// [`crate::engine::message::WorkerEvent::WorkerFailed`].
    pub heartbeat: AtomicU64,
    /// When set, the worker maintains `key_counts` (per-key workload
    /// distribution — what SBK-style mitigation needs, §3.3.1: "SBK
    /// requires the workers to store the distribution of workload per
    /// key").
    pub track_keys: AtomicBool,
    /// Input tuples seen per partitioning-key hash. Written once per
    /// batch (the worker accumulates into a thread-local map and
    /// merges at batch boundaries), so this lock is off the per-tuple
    /// hot path; readers (the Reshape plugin, baselines) take it at
    /// metric-tick cadence.
    pub key_counts: Mutex<std::collections::HashMap<u64, u64>>,
}

impl WorkerGauges {
    /// Busy fraction in [0,1] since start.
    pub fn busy_fraction(&self, now: Instant, start: Instant) -> f64 {
        let alive = now.duration_since(start).as_nanos() as f64;
        if alive <= 0.0 {
            return 0.0;
        }
        (self.busy_ns.load(Ordering::Relaxed) as f64 / alive).clamp(0.0, 1.0)
    }
}

/// Receive-side errors of the data ring (mirrors `std::mpsc`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingRecvError {
    /// Nothing queued (`try_recv`) / nothing arrived in time
    /// (`recv_timeout`).
    Empty,
    /// Every sender handle dropped and the ring is drained.
    Disconnected,
}

/// `try_send` failure: the sender's lane was full, or the receiver
/// died. Carries the event back to the caller either way.
#[derive(Debug)]
pub enum RingTrySendError {
    Full(DataEvent),
    Disconnected(DataEvent),
}

/// One sender's private FIFO into the receiver. Single producer (the
/// owning [`DataSender`]), single consumer (the ring's receiver): the
/// `events` mutex is therefore at most 1-vs-1 contended, and only when
/// the consumer happens to drain this exact lane mid-push.
struct Lane {
    events: Mutex<VecDeque<DataEvent>>,
    /// Queued events in this lane (producer adds, consumer subtracts).
    len: AtomicUsize,
    /// False once the owning sender handle dropped; a dead lane is
    /// pruned by the consumer after it drains.
    tx_alive: AtomicBool,
}

impl Lane {
    fn new(cap: usize) -> Lane {
        Lane {
            events: Mutex::new(VecDeque::with_capacity(cap)),
            len: AtomicUsize::new(0),
            tx_alive: AtomicBool::new(true),
        }
    }
}

/// A bounded data ring of per-sender SPSC lanes with lazy Condvar
/// parking (no spin on full or empty): the worker's data plane. Single
/// consumer (the owning worker); each producer ([`DataSender`] clone)
/// owns one bounded lane, so producers never serialize on each other.
/// Blocking `send` on a full lane is the §2.3.3 congestion-control
/// backpressure, applied per congesting sender.
///
/// The wakeup protocol is Dekker-style over SeqCst atomics: a parking
/// side re-checks its condition while holding the shared `wake` lock,
/// and the waking side notifies under that same lock only when the
/// `rx_waiting`/`tx_waiting` flags say someone actually parked — so
/// the hot path never takes `wake`, and no wakeup can be lost.
pub struct DataRing {
    /// Per-lane capacity (events).
    cap: usize,
    /// Lane registry. Locked only to append (sender clone), to scan on
    /// a non-empty pop, and to prune drained dead lanes — never held
    /// while parking.
    lanes: Mutex<Vec<Arc<Lane>>>,
    /// Shared parking lock for both directions (never held while
    /// holding a lane's `events` lock).
    wake: Mutex<()>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Ring-wide queued-event count: the consumer's lock-free empty
    /// check.
    total_len: AtomicUsize,
    /// Live `DataSender` handles (0 + drained ⇒ disconnected).
    sender_count: AtomicUsize,
    /// Receiver alive? (false once the worker's `Mailbox` dropped).
    rx_alive: AtomicBool,
    /// Consumer parked on empty.
    rx_waiting: AtomicBool,
    /// Producers parked on full lanes.
    tx_waiting: AtomicUsize,
    /// Round-robin drain position (single consumer; no contention).
    cursor: AtomicUsize,
}

impl DataRing {
    /// A ring with `cap`-slot lanes and one live sender handle (the
    /// one [`mailbox`] returns).
    fn new(cap: usize) -> (Arc<DataRing>, Arc<Lane>) {
        let cap = cap.max(1);
        let lane = Arc::new(Lane::new(cap));
        let ring = Arc::new(DataRing {
            cap,
            lanes: Mutex::new(vec![lane.clone()]),
            wake: Mutex::new(()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            total_len: AtomicUsize::new(0),
            sender_count: AtomicUsize::new(1),
            rx_alive: AtomicBool::new(true),
            rx_waiting: AtomicBool::new(false),
            tx_waiting: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
        });
        (ring, lane)
    }

    /// Register a fresh lane for a cloned sender.
    fn add_sender(&self) -> Arc<Lane> {
        let lane = Arc::new(Lane::new(self.cap));
        lock_ok(&self.lanes).push(lane.clone());
        self.sender_count.fetch_add(1, Ordering::SeqCst);
        lane
    }

    fn drop_sender(&self, lane: &Lane) {
        lane.tx_alive.store(false, Ordering::SeqCst);
        if self.sender_count.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last sender gone: wake a parked consumer so it can
            // observe the disconnect. Taking the wake lock orders this
            // after any in-progress recv's park decision.
            let _g = lock_ok(&self.wake);
            self.not_empty.notify_all();
        }
    }

    fn close_rx(&self) {
        self.rx_alive.store(false, Ordering::SeqCst);
        // Unbuffered senders must not block forever on a dead worker.
        let _g = lock_ok(&self.wake);
        self.not_full.notify_all();
    }

    /// Push one event onto `lane`; blocks on a full lane when `block`,
    /// else returns the event.
    fn push(&self, lane: &Lane, ev: DataEvent, block: bool) -> Result<(), RingTrySendError> {
        loop {
            if !self.rx_alive.load(Ordering::SeqCst) {
                return Err(RingTrySendError::Disconnected(ev));
            }
            if lane.len.load(Ordering::SeqCst) < self.cap {
                lock_ok(&lane.events).push_back(ev);
                lane.len.fetch_add(1, Ordering::SeqCst);
                self.total_len.fetch_add(1, Ordering::SeqCst);
                // Lazy wake: only if the consumer actually parked. The
                // consumer re-checks `total_len` under `wake` before
                // sleeping, so this SeqCst pair cannot lose a wakeup.
                if self.rx_waiting.load(Ordering::SeqCst) {
                    let _g = lock_ok(&self.wake);
                    self.not_empty.notify_all();
                }
                return Ok(());
            }
            if !block {
                return Err(RingTrySendError::Full(ev));
            }
            // Park until the consumer frees a slot in this lane (or
            // hangs up). The condition re-check happens under `wake`.
            let mut g = lock_ok(&self.wake);
            self.tx_waiting.fetch_add(1, Ordering::SeqCst);
            while lane.len.load(Ordering::SeqCst) >= self.cap
                && self.rx_alive.load(Ordering::SeqCst)
            {
                g = self.not_full.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            self.tx_waiting.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Scan the lanes round-robin and pop one event. Prunes drained
    /// lanes of dropped senders along the way.
    fn pop_any(&self) -> Option<DataEvent> {
        let mut lanes = lock_ok(&self.lanes);
        let n = lanes.len();
        if n == 0 {
            return None;
        }
        let start = self.cursor.load(Ordering::Relaxed);
        for k in 0..n {
            let i = (start + k) % n;
            if lanes[i].len.load(Ordering::SeqCst) == 0 {
                continue;
            }
            let lane = lanes[i].clone();
            let ev = lock_ok(&lane.events).pop_front();
            let Some(ev) = ev else { continue };
            lane.len.fetch_sub(1, Ordering::SeqCst);
            self.total_len.fetch_sub(1, Ordering::SeqCst);
            self.cursor.store((i + 1) % n, Ordering::Relaxed);
            drop(lanes);
            if self.tx_waiting.load(Ordering::SeqCst) > 0 {
                let _g = lock_ok(&self.wake);
                self.not_full.notify_all();
            }
            return Some(ev);
        }
        // Nothing queued anywhere: retire lanes whose sender dropped
        // (nobody can ever push to them again).
        if lanes
            .iter()
            .any(|l| !l.tx_alive.load(Ordering::SeqCst) && l.len.load(Ordering::SeqCst) == 0)
        {
            lanes.retain(|l| {
                l.tx_alive.load(Ordering::SeqCst) || l.len.load(Ordering::SeqCst) > 0
            });
            self.cursor.store(0, Ordering::Relaxed);
        }
        None
    }

    fn try_recv(&self) -> Result<DataEvent, RingRecvError> {
        // Fast path: one atomic load when idle (the DP loop polls this
        // between control checks).
        if self.total_len.load(Ordering::SeqCst) == 0 {
            return if self.sender_count.load(Ordering::SeqCst) == 0 {
                Err(RingRecvError::Disconnected)
            } else {
                Err(RingRecvError::Empty)
            };
        }
        match self.pop_any() {
            Some(ev) => Ok(ev),
            None if self.sender_count.load(Ordering::SeqCst) == 0 => {
                Err(RingRecvError::Disconnected)
            }
            None => Err(RingRecvError::Empty),
        }
    }

    fn recv_deadline(&self, deadline: Option<Instant>) -> Result<DataEvent, RingRecvError> {
        loop {
            if let Some(ev) = self.pop_any() {
                return Ok(ev);
            }
            if self.sender_count.load(Ordering::SeqCst) == 0 {
                return Err(RingRecvError::Disconnected);
            }
            // Park. Announce first, then re-check the condition under
            // the wake lock: a sender that missed `rx_waiting == true`
            // must have completed its `total_len` increment before our
            // re-check (SeqCst), so we either see the event or the
            // sender sees the flag.
            let mut g = lock_ok(&self.wake);
            self.rx_waiting.store(true, Ordering::SeqCst);
            if self.total_len.load(Ordering::SeqCst) > 0
                || self.sender_count.load(Ordering::SeqCst) == 0
            {
                self.rx_waiting.store(false, Ordering::SeqCst);
                continue;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        self.rx_waiting.store(false, Ordering::SeqCst);
                        return Err(RingRecvError::Empty);
                    }
                    let (gg, _) = self
                        .not_empty
                        .wait_timeout(g, d - now)
                        .unwrap_or_else(|e| e.into_inner());
                    g = gg;
                }
                None => {
                    g = self.not_empty.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            }
            self.rx_waiting.store(false, Ordering::SeqCst);
            drop(g);
        }
    }
}

/// The sending half of a worker's data plane: a private SPSC lane into
/// the receiver's ring plus the receiver's gauges, so the sender
/// maintains the queue-size metric. Cloning creates a fresh lane and
/// tracks liveness (`std::mpsc`-style disconnect when the last clone
/// drops).
pub struct DataSender {
    ring: Arc<DataRing>,
    lane: Arc<Lane>,
    pub gauges: Arc<WorkerGauges>,
}

impl Clone for DataSender {
    fn clone(&self) -> DataSender {
        let lane = self.ring.add_sender();
        DataSender { ring: self.ring.clone(), lane, gauges: self.gauges.clone() }
    }
}

impl Drop for DataSender {
    fn drop(&mut self) {
        self.ring.drop_sender(&self.lane);
    }
}

impl DataSender {
    /// Send a data event, blocking if this sender's lane is full
    /// (congestion control / backpressure).
    pub fn send(&self, ev: DataEvent) -> Result<(), ()> {
        if let DataEvent::Batch(b) = &ev {
            self.gauges
                .queued
                .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
        }
        // Blocking send (FIFO per sender, bounded); error only if the
        // receiver hung up (crash/teardown).
        self.ring.push(&self.lane, ev, true).map_err(|_| ())
    }
}

/// The receiving half of the data ring (single consumer).
pub struct RingReceiver {
    ring: Arc<DataRing>,
}

impl Drop for RingReceiver {
    fn drop(&mut self) {
        self.ring.close_rx();
    }
}

impl RingReceiver {
    /// Non-blocking pop; `Empty` costs one atomic load.
    pub fn try_recv(&self) -> Result<DataEvent, RingRecvError> {
        self.ring.try_recv()
    }

    /// Blocking pop (tests / drain loops).
    pub fn recv(&self) -> Result<DataEvent, RingRecvError> {
        self.ring.recv_deadline(None)
    }

    /// Pop, parking for at most `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<DataEvent, RingRecvError> {
        self.ring.recv_deadline(Some(Instant::now() + timeout))
    }
}

/// The receiving half: data ring + control inbox + gauges.
pub struct Mailbox {
    pub data: RingReceiver,
    pub control: Arc<ControlInbox>,
    pub gauges: Arc<WorkerGauges>,
}

/// Create the mailbox for one worker; returns the sender template
/// (cloning it gives each upstream producer its own SPSC lane).
pub fn mailbox(cap: usize) -> (DataSender, Mailbox) {
    let (ring, lane) = DataRing::new(cap);
    let gauges = Arc::new(WorkerGauges::default());
    let control = Arc::new(ControlInbox::new());
    (
        DataSender { ring: ring.clone(), lane, gauges: gauges.clone() },
        Mailbox { data: RingReceiver { ring }, control, gauges },
    )
}

/// Non-blocking send helper used in tests.
pub fn try_send(s: &DataSender, ev: DataEvent) -> Result<(), RingTrySendError> {
    if let DataEvent::Batch(b) = &ev {
        s.gauges
            .queued
            .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
    }
    s.ring.push(&s.lane, ev, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::message::{DataMessage, WorkerId};
    use crate::tuple::{Tuple, Value};

    fn batch(n: usize) -> DataEvent {
        DataEvent::Batch(DataMessage {
            from: WorkerId::new(0, 0),
            port: 0,
            seq: 0,
            batch: (0..n).map(|i| Tuple::new(vec![Value::Int(i as i64)])).collect(),
            hashes: None,
        })
    }

    fn seq_msg(from: WorkerId, seq: u64) -> DataEvent {
        DataEvent::Batch(DataMessage {
            from,
            port: 0,
            seq,
            batch: crate::tuple::TupleBatch::empty(),
            hashes: None,
        })
    }

    #[test]
    fn control_inbox_immediate() {
        let inbox = ControlInbox::new();
        assert!(!inbox.maybe_pending());
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        assert!(inbox.maybe_pending());
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(inbox.try_recv().is_none());
    }

    #[test]
    fn control_inbox_respects_delay() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(50));
        // Not yet due.
        assert!(inbox.try_recv().is_none());
        std::thread::sleep(Duration::from_millis(60));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn control_inbox_fifo() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
    }

    #[test]
    fn control_inbox_fifo_among_equal_due_times() {
        // Same artificial delay ⇒ same due instant is possible; the
        // arrival sequence must break the tie FIFO.
        let inbox = ControlInbox::new();
        for _ in 0..5 {
            inbox.send(ControlMessage::Pause, Duration::ZERO);
            inbox.send(ControlMessage::Resume, Duration::ZERO);
        }
        for _ in 0..5 {
            assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
            assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
        }
    }

    #[test]
    fn delayed_head_does_not_block_due_message() {
        // A front message with an artificial delivery delay must not
        // hide an already-due message queued behind it.
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(250));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
        // The delayed head is still queued but not yet due.
        assert!(inbox.try_recv().is_none());
        assert!(inbox.maybe_pending());
        std::thread::sleep(Duration::from_millis(260));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn recv_timeout_skips_delayed_head() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_secs(60));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let t0 = Instant::now();
        let got = inbox.recv_timeout(Duration::from_secs(5));
        assert!(matches!(got, Some(ControlMessage::Resume)));
        assert!(t0.elapsed() < Duration::from_secs(1), "blocked on delayed head");
    }

    #[test]
    fn recv_timeout_wakes_on_send() {
        let inbox = Arc::new(ControlInbox::new());
        let i2 = inbox.clone();
        let h = std::thread::spawn(move || i2.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let got = h.join().unwrap();
        assert!(matches!(got, Some(ControlMessage::Resume)));
    }

    #[test]
    fn recv_timeout_times_out() {
        let inbox = ControlInbox::new();
        let t0 = Instant::now();
        assert!(inbox.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn gauges_track_queue_size() {
        let (tx, mb) = mailbox(8);
        tx.send(batch(5)).unwrap();
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 5);
        // Receiver drains and decrements per batch (done by worker
        // loop; simulate here).
        if let Ok(DataEvent::Batch(b)) = mb.data.try_recv() {
            mb.gauges
                .queued
                .fetch_sub(b.batch.len() as i64, Ordering::Relaxed);
        }
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn data_ring_fifo_per_sender() {
        let (tx, mb) = mailbox(16);
        for seq in 0..5u64 {
            tx.send(seq_msg(WorkerId::new(0, 0), seq)).unwrap();
        }
        for seq in 0..5u64 {
            match mb.data.recv().unwrap() {
                DataEvent::Batch(b) => assert_eq!(b.seq, seq),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn spsc_lanes_keep_per_sender_fifo_under_interleaving() {
        // Two senders interleave; each sender's stream must drain in
        // its own seq order, whatever the round-robin interleaving.
        let (tx_a, mb) = mailbox(64);
        let tx_b = tx_a.clone();
        for seq in 0..10u64 {
            tx_a.send(seq_msg(WorkerId::new(0, 0), seq)).unwrap();
            tx_b.send(seq_msg(WorkerId::new(0, 1), seq)).unwrap();
        }
        let mut next = std::collections::HashMap::new();
        for _ in 0..20 {
            match mb.data.recv().unwrap() {
                DataEvent::Batch(b) => {
                    let n = next.entry(b.from).or_insert(0u64);
                    assert_eq!(b.seq, *n, "lane {} out of order", b.from);
                    *n += 1;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(next.len(), 2);
    }

    #[test]
    fn concurrent_senders_deliver_everything_in_lane_order() {
        // Stress the SPSC paths: 4 producer threads × 200 events each
        // against a tiny lane cap (forced parking both directions).
        let (tx0, mb) = mailbox(4);
        let mut handles = Vec::new();
        for s in 0..4usize {
            let tx = tx0.clone();
            handles.push(std::thread::spawn(move || {
                for seq in 0..200u64 {
                    tx.send(seq_msg(WorkerId::new(0, s), seq)).unwrap();
                }
            }));
        }
        drop(tx0);
        let mut next = std::collections::HashMap::new();
        let mut got = 0;
        loop {
            match mb.data.recv_timeout(Duration::from_secs(10)) {
                Ok(DataEvent::Batch(b)) => {
                    let n = next.entry(b.from).or_insert(0u64);
                    assert_eq!(b.seq, *n, "lane {} out of order", b.from);
                    *n += 1;
                    got += 1;
                }
                Ok(other) => panic!("unexpected {other:?}"),
                Err(RingRecvError::Disconnected) => break,
                Err(RingRecvError::Empty) => panic!("timed out at {got} events"),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got, 800);
    }

    #[test]
    fn data_ring_backpressure_blocks_until_pop() {
        let (tx, mb) = mailbox(2);
        tx.send(batch(1)).unwrap();
        tx.send(batch(1)).unwrap();
        // Full: try_send bounces; a blocking send parks until a pop
        // frees a slot (join would hang forever if the parked sender
        // were never woken).
        assert!(matches!(try_send(&tx, batch(1)), Err(RingTrySendError::Full(_))));
        let t2 = tx.clone();
        // The clone has its own lane with free slots; fill it so the
        // spawned blocking send actually parks on a full lane.
        t2.send(batch(1)).unwrap();
        t2.send(batch(1)).unwrap();
        let h = std::thread::spawn(move || t2.send(batch(1)).unwrap());
        std::thread::sleep(Duration::from_millis(40));
        mb.data.recv().unwrap(); // frees one slot
        h.join().unwrap();
        // All remaining events drain.
        for _ in 0..4 {
            assert!(mb.data.recv().is_ok());
        }
    }

    #[test]
    fn data_ring_disconnects_when_all_senders_drop() {
        let (tx, mb) = mailbox(4);
        let tx2 = tx.clone();
        tx.send(batch(1)).unwrap();
        drop(tx);
        // A live clone keeps the ring connected, and the dropped
        // sender's lane still drains.
        assert!(matches!(mb.data.try_recv(), Ok(_)));
        assert!(matches!(mb.data.try_recv(), Err(RingRecvError::Empty)));
        drop(tx2);
        assert!(matches!(
            mb.data.recv_timeout(Duration::from_secs(1)),
            Err(RingRecvError::Disconnected)
        ));
    }

    #[test]
    fn data_ring_send_errors_after_receiver_drop() {
        let (tx, mb) = mailbox(4);
        drop(mb);
        assert!(tx.send(batch(1)).is_err());
    }

    #[test]
    fn poisoned_locks_do_not_cascade() {
        // A thread panicking while holding a shared gauge lock must
        // not take the whole channel down: peers recover the guard and
        // the data plane keeps moving (the silent-death bug class —
        // one panic poisoning its neighbors — is contained).
        let (tx, mb) = mailbox(4);
        let g = mb.gauges.clone();
        let _ = std::thread::spawn(move || {
            let _guard = g.key_counts.lock().unwrap();
            panic!("injected poison");
        })
        .join();
        assert!(mb.gauges.key_counts.lock().is_err(), "lock should be poisoned");
        let n = mb
            .gauges
            .key_counts
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .len();
        assert_eq!(n, 0);
        tx.send(batch(1)).unwrap();
        assert!(mb.data.try_recv().is_ok());
    }

    #[test]
    fn data_ring_recv_timeout_wakes_on_send() {
        let (tx, mb) = mailbox(4);
        let h = std::thread::spawn(move || mb.data.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        tx.send(batch(1)).unwrap();
        assert!(h.join().unwrap().is_ok());
    }
}

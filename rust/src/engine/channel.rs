//! Actor mailboxes: bounded FIFO data channels and the expedited
//! control inbox.
//!
//! The paper's §2.4.2 problem — a FIFO actor mailbox buries control
//! messages behind queued data — is solved there by delegating data
//! processing to a DP thread that checks a shared `Paused` flag per
//! tuple. We implement the same structure natively: the data plane is a
//! bounded `std::sync::mpsc::sync_channel` (congestion control, §2.3.3)
//! and the control plane is a dedicated [`ControlInbox`] with an atomic
//! `pending` flag the DP loop reads between tuples (a single relaxed
//! atomic load on the hot path).
//!
//! The inbox supports an artificial delivery delay (per-message due
//! time) used by the Fig. 3.21 control-latency experiment. Receivers
//! always dequeue the *earliest-due* message rather than the queue
//! front, so a delayed message cannot head-of-line-block an already-due
//! one behind it.

use crate::engine::message::{ControlMessage, DataEvent};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Control inbox shared between the coordinator (producer) and one
/// worker (consumer).
pub struct ControlInbox {
    queue: Mutex<VecDeque<(Instant, ControlMessage)>>,
    pending: AtomicBool,
    cv: Condvar,
}

impl Default for ControlInbox {
    fn default() -> Self {
        ControlInbox::new()
    }
}

impl ControlInbox {
    pub fn new() -> ControlInbox {
        ControlInbox {
            queue: Mutex::new(VecDeque::new()),
            pending: AtomicBool::new(false),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a control message, optionally due only after `delay`
    /// (simulated delivery latency; 0 = immediate).
    pub fn send(&self, msg: ControlMessage, delay: Duration) {
        let due = Instant::now() + delay;
        let mut q = self.queue.lock().unwrap();
        q.push_back((due, msg));
        // The flag is best-effort: the consumer re-checks due times.
        self.pending.store(true, Ordering::Release);
        self.cv.notify_one();
    }

    /// Cheap hot-path check: is a message *possibly* ready?
    #[inline]
    pub fn maybe_pending(&self) -> bool {
        self.pending.load(Ordering::Acquire)
    }

    /// Index of the earliest-due message (first wins among equal due
    /// times, preserving FIFO for undelayed messages). Receivers must
    /// scan rather than peek the front: a front message carrying an
    /// artificial delivery delay would otherwise hide an already-due
    /// message queued behind it (head-of-line blocking).
    fn earliest_idx(q: &VecDeque<(Instant, ControlMessage)>) -> Option<usize> {
        let mut best: Option<(usize, Instant)> = None;
        for (i, (due, _)) in q.iter().enumerate() {
            if best.map_or(true, |(_, b)| *due < b) {
                best = Some((i, *due));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Dequeue the earliest *due* message, if any.
    pub fn try_recv(&self) -> Option<ControlMessage> {
        if !self.maybe_pending() {
            return None;
        }
        let mut q = self.queue.lock().unwrap();
        let now = Instant::now();
        if let Some(idx) = Self::earliest_idx(&q) {
            if q[idx].0 <= now {
                let (_, msg) = q.remove(idx).unwrap();
                if q.is_empty() {
                    self.pending.store(false, Ordering::Release);
                }
                return Some(msg);
            }
        }
        None
    }

    /// Block until a message is due or `timeout` elapses.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<ControlMessage> {
        let deadline = Instant::now() + timeout;
        let mut q = self.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            if let Some(idx) = Self::earliest_idx(&q) {
                let due = q[idx].0;
                if due <= now {
                    let (_, msg) = q.remove(idx).unwrap();
                    if q.is_empty() {
                        self.pending.store(false, Ordering::Release);
                    }
                    return Some(msg);
                }
                // Wait until the earliest message becomes due (or the
                // deadline passes).
                if now >= deadline {
                    return None;
                }
                let wait = due.min(deadline).saturating_duration_since(now);
                let (qq, _) = self.cv.wait_timeout(q, wait.max(Duration::from_micros(50))).unwrap();
                q = qq;
            } else {
                if now >= deadline {
                    return None;
                }
                let (qq, _) = self
                    .cv
                    .wait_timeout(q, deadline - now)
                    .unwrap();
                q = qq;
            }
        }
    }
}

/// Shared per-worker workload gauges, readable by the coordinator
/// without a control round-trip (the paper's "controller periodically
/// collects workload metrics", §3.2.1, at 1–2% overhead, Fig. 3.25).
#[derive(Default)]
pub struct WorkerGauges {
    /// Unprocessed input tuples (senders add, the DP loop subtracts) —
    /// Reshape's default workload metric φ_w.
    pub queued: AtomicI64,
    /// Total tuples processed.
    pub processed: AtomicI64,
    /// Total tuples produced (output).
    pub produced: AtomicI64,
    /// Total tuples received, by *final* routed destination accounting:
    /// incremented by senders when routing a tuple here (σ_w, the
    /// "total input received", §3.4.1).
    pub received: AtomicI64,
    /// Tuples this worker would have received under the *base*
    /// partitioning, ignoring mitigation overlays — the estimator's
    /// input for predicting a worker's natural future share (§3.3.2).
    pub base_received: AtomicI64,
    /// Nanoseconds spent busy (processing tuples) — the Flink-style
    /// `busyTimeMsPerSecond` metric base (§3.7.12).
    pub busy_ns: AtomicI64,
    /// Nanoseconds alive (set once the worker starts).
    pub alive_since_ns: AtomicI64,
    /// When set, the worker maintains `key_counts` (per-key workload
    /// distribution — what SBK-style mitigation needs, §3.3.1: "SBK
    /// requires the workers to store the distribution of workload per
    /// key").
    pub track_keys: AtomicBool,
    /// Input tuples seen per partitioning-key hash.
    pub key_counts: Mutex<std::collections::HashMap<u64, u64>>,
}

impl WorkerGauges {
    /// Busy fraction in [0,1] since start.
    pub fn busy_fraction(&self, now: Instant, start: Instant) -> f64 {
        let alive = now.duration_since(start).as_nanos() as f64;
        if alive <= 0.0 {
            return 0.0;
        }
        (self.busy_ns.load(Ordering::Relaxed) as f64 / alive).clamp(0.0, 1.0)
    }
}

/// The sending half of a worker's data plane: a sync sender plus the
/// receiver's gauges so the sender can maintain the queue-size metric.
#[derive(Clone)]
pub struct DataSender {
    pub tx: SyncSender<DataEvent>,
    pub gauges: Arc<WorkerGauges>,
}

impl DataSender {
    /// Send a data event, blocking if the receiver's queue is full
    /// (congestion control / backpressure).
    pub fn send(&self, ev: DataEvent) -> Result<(), ()> {
        if let DataEvent::Batch(b) = &ev {
            self.gauges
                .queued
                .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
        }
        // Blocking send (FIFO, bounded — the paper's congestion
        // control); error only if the receiver hung up (crash).
        self.tx.send(ev).map_err(|_| ())
    }
}

/// The receiving half: data receiver + control inbox + gauges.
pub struct Mailbox {
    pub data: Receiver<DataEvent>,
    pub control: Arc<ControlInbox>,
    pub gauges: Arc<WorkerGauges>,
}

/// Create the mailbox for one worker; returns the sender template.
pub fn mailbox(cap: usize) -> (DataSender, Mailbox) {
    let (tx, rx) = std::sync::mpsc::sync_channel(cap);
    let gauges = Arc::new(WorkerGauges::default());
    let control = Arc::new(ControlInbox::new());
    (
        DataSender { tx, gauges: gauges.clone() },
        Mailbox { data: rx, control, gauges },
    )
}

/// Non-blocking send helper used in tests.
pub fn try_send(s: &DataSender, ev: DataEvent) -> Result<(), TrySendError<DataEvent>> {
    if let DataEvent::Batch(b) = &ev {
        s.gauges
            .queued
            .fetch_add(b.batch.len() as i64, Ordering::Relaxed);
    }
    s.tx.try_send(ev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::message::{DataMessage, WorkerId};
    use crate::tuple::{Tuple, Value};

    fn batch(n: usize) -> DataEvent {
        DataEvent::Batch(DataMessage {
            from: WorkerId::new(0, 0),
            port: 0,
            seq: 0,
            batch: (0..n).map(|i| Tuple::new(vec![Value::Int(i as i64)])).collect(),
        })
    }

    #[test]
    fn control_inbox_immediate() {
        let inbox = ControlInbox::new();
        assert!(!inbox.maybe_pending());
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        assert!(inbox.maybe_pending());
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(inbox.try_recv().is_none());
    }

    #[test]
    fn control_inbox_respects_delay() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(50));
        // Not yet due.
        assert!(inbox.try_recv().is_none());
        std::thread::sleep(Duration::from_millis(60));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn control_inbox_fifo() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::ZERO);
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
    }

    #[test]
    fn delayed_head_does_not_block_due_message() {
        // A front message with an artificial delivery delay must not
        // hide an already-due message queued behind it.
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_millis(250));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Resume)));
        // The delayed head is still queued but not yet due.
        assert!(inbox.try_recv().is_none());
        assert!(inbox.maybe_pending());
        std::thread::sleep(Duration::from_millis(260));
        assert!(matches!(inbox.try_recv(), Some(ControlMessage::Pause)));
    }

    #[test]
    fn recv_timeout_skips_delayed_head() {
        let inbox = ControlInbox::new();
        inbox.send(ControlMessage::Pause, Duration::from_secs(60));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let t0 = Instant::now();
        let got = inbox.recv_timeout(Duration::from_secs(5));
        assert!(matches!(got, Some(ControlMessage::Resume)));
        assert!(t0.elapsed() < Duration::from_secs(1), "blocked on delayed head");
    }

    #[test]
    fn recv_timeout_wakes_on_send() {
        let inbox = Arc::new(ControlInbox::new());
        let i2 = inbox.clone();
        let h = std::thread::spawn(move || i2.recv_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        inbox.send(ControlMessage::Resume, Duration::ZERO);
        let got = h.join().unwrap();
        assert!(matches!(got, Some(ControlMessage::Resume)));
    }

    #[test]
    fn recv_timeout_times_out() {
        let inbox = ControlInbox::new();
        let t0 = Instant::now();
        assert!(inbox.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn gauges_track_queue_size() {
        let (tx, mb) = mailbox(8);
        tx.send(batch(5)).unwrap();
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 5);
        // Receiver drains and decrements per tuple (done by worker loop;
        // simulate here).
        if let Ok(DataEvent::Batch(b)) = mb.data.try_recv() {
            mb.gauges
                .queued
                .fetch_sub(b.batch.len() as i64, Ordering::Relaxed);
        }
        assert_eq!(mb.gauges.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn data_channel_fifo_per_sender() {
        let (tx, mb) = mailbox(16);
        for seq in 0..5u64 {
            tx.send(DataEvent::Batch(DataMessage {
                from: WorkerId::new(0, 0),
                port: 0,
                seq,
                batch: crate::tuple::TupleBatch::empty(),
            }))
            .unwrap();
        }
        for seq in 0..5u64 {
            match mb.data.recv().unwrap() {
                DataEvent::Batch(b) => assert_eq!(b.seq, seq),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}

//! Global conditional breakpoints (§2.5.3): the coordinator-side
//! target-splitting protocol.
//!
//! A global predicate — "operator O has produced N tuples" (COUNT) or
//! "the sum of field f over O's output exceeds S" (SUM) — cannot be
//! checked by one worker. The principal splits the target equally among
//! the workers; each worker pauses itself upon reaching its share and
//! reports. The principal waits a threshold τ for the rest, then
//! *inquires* them (they pause and report progress), computes the
//! remaining target, and either declares a **hit**, reassigns the
//! remainder evenly (resuming everyone at full parallelism), or — when
//! the remainder is too small for parallelism to help — assigns it to a
//! single worker (Fig. 2.5, times t₀–t₁₀; SUM overshoot-minimization of
//! the "give the tail to one worker" rule).
//!
//! The struct is a pure state machine (no channels, no clock reads) so
//! the protocol is unit-testable deterministically; the coordinator
//! feeds it events and timeouts.

/// What the coordinator must do next.
#[derive(Debug, PartialEq)]
pub enum BpAction {
    /// Nothing; keep waiting.
    None,
    /// Start the τ timer (a worker reached its target; wait for others).
    StartTimer,
    /// Send `Inquire` to these worker indices.
    Inquire(Vec<usize>),
    /// Assign new targets: (worker idx, amount). Workers resume on
    /// assignment.
    Assign(Vec<(usize, f64)>),
    /// The breakpoint condition is met: pause the whole workflow.
    Hit,
}

/// Phase of the protocol ("normal processing" vs "synchronization
/// state" in §2.5.3's terminology).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Workers are processing against assigned targets.
    Normal,
    /// Waiting out τ after the first `TargetReached`.
    AwaitOthers,
    /// Inquiries sent; waiting for all reports.
    Synchronizing,
}

/// A COUNT or SUM global breakpoint on one operator's output.
#[derive(Debug)]
pub struct GlobalBreakpoint {
    pub id: u64,
    /// Total remaining amount (decremented as reports arrive).
    remaining: f64,
    /// SUM field, or None for COUNT.
    pub sum_field: Option<usize>,
    /// Below this remainder, assign everything to a single worker
    /// (COUNT: 1.0; SUM: caller-chosen based on the value distribution).
    single_worker_threshold: f64,
    workers: usize,
    phase: Phase,
    /// Per-worker: has an outstanding (unreported) assignment.
    outstanding: Vec<bool>,
    /// Reports received in the current round: (produced amount).
    reported: Vec<Option<f64>>,
    /// Assignment currently held by each worker.
    assigned: Vec<f64>,
}

impl GlobalBreakpoint {
    /// COUNT breakpoint: hit when the operator has produced `total`
    /// tuples.
    pub fn count(id: u64, total: u64, workers: usize) -> GlobalBreakpoint {
        GlobalBreakpoint {
            id,
            remaining: total as f64,
            sum_field: None,
            single_worker_threshold: 1.0,
            workers,
            phase: Phase::Normal,
            outstanding: vec![false; workers],
            reported: vec![None; workers],
            assigned: vec![0.0; workers],
        }
    }

    /// SUM breakpoint: hit when Σ field ≥ `total`. `tail` is the
    /// threshold below which the whole remainder goes to one worker to
    /// minimize overshoot (§2.5.3's SUM discussion).
    pub fn sum(id: u64, total: f64, field: usize, workers: usize, tail: f64) -> GlobalBreakpoint {
        GlobalBreakpoint {
            id,
            remaining: total,
            sum_field: Some(field),
            single_worker_threshold: tail,
            workers,
            phase: Phase::Normal,
            outstanding: vec![false; workers],
            reported: vec![None; workers],
            assigned: vec![0.0; workers],
        }
    }

    /// Initial split: equal shares to all workers (t₀ in Fig. 2.5).
    pub fn initial_assignments(&mut self) -> Vec<(usize, f64)> {
        self.split_evenly()
    }

    fn split_evenly(&mut self) -> Vec<(usize, f64)> {
        self.phase = Phase::Normal;
        self.reported = vec![None; self.workers];
        let mut out = Vec::with_capacity(self.workers);
        if self.remaining <= self.single_worker_threshold {
            // Tail: one worker gets the rest; the others stay paused
            // (overshoot minimization / no parallelism gain).
            let w = 0;
            self.outstanding = vec![false; self.workers];
            self.outstanding[w] = true;
            self.assigned = vec![0.0; self.workers];
            self.assigned[w] = self.remaining;
            out.push((w, self.remaining));
            return out;
        }
        let share = if self.sum_field.is_none() {
            // COUNT: integral shares; distribute the remainder of the
            // division one extra tuple each.
            (self.remaining / self.workers as f64).floor()
        } else {
            self.remaining / self.workers as f64
        };
        let mut leftover = if self.sum_field.is_none() {
            self.remaining - share * self.workers as f64
        } else {
            0.0
        };
        for w in 0..self.workers {
            let mut amt = share;
            if leftover >= 1.0 {
                amt += 1.0;
                leftover -= 1.0;
            }
            if amt <= 0.0 {
                self.outstanding[w] = false;
                self.assigned[w] = 0.0;
                continue;
            }
            self.outstanding[w] = true;
            self.assigned[w] = amt;
            out.push((w, amt));
        }
        out
    }

    /// A worker reached its target and paused itself.
    pub fn on_target_reached(&mut self, w: usize, produced: f64) -> BpAction {
        self.reported[w] = Some(produced);
        self.outstanding[w] = false;
        self.remaining -= produced;
        if self.all_reported() {
            return self.conclude_round();
        }
        match self.phase {
            Phase::Normal => {
                // If everything still outstanding is a tail the others
                // are already working on, just keep waiting (the t₉
                // "don't inquire for one remaining tuple" rule).
                let outstanding_total: f64 = self
                    .assigned
                    .iter()
                    .zip(&self.outstanding)
                    .filter(|(_, o)| **o)
                    .map(|(a, _)| *a)
                    .sum();
                if outstanding_total <= self.single_worker_threshold {
                    self.phase = Phase::AwaitOthers;
                    return BpAction::None;
                }
                self.phase = Phase::AwaitOthers;
                BpAction::StartTimer
            }
            _ => BpAction::None,
        }
    }

    /// The τ timer fired: inquire workers that have not reported.
    pub fn on_timeout(&mut self) -> BpAction {
        if self.phase != Phase::AwaitOthers {
            return BpAction::None;
        }
        let missing: Vec<usize> = (0..self.workers)
            .filter(|&w| self.reported[w].is_none() && self.outstanding[w])
            .collect();
        if missing.is_empty() {
            return self.conclude_round();
        }
        self.phase = Phase::Synchronizing;
        BpAction::Inquire(missing)
    }

    /// An inquiry reply (worker paused itself and reported progress).
    pub fn on_inquiry_report(&mut self, w: usize, produced: f64) -> BpAction {
        self.reported[w] = Some(produced);
        self.outstanding[w] = false;
        self.remaining -= produced;
        if self.all_reported() {
            self.conclude_round()
        } else {
            BpAction::None
        }
    }

    fn all_reported(&self) -> bool {
        (0..self.workers).all(|w| self.reported[w].is_some() || !self.outstanding[w])
    }

    fn conclude_round(&mut self) -> BpAction {
        if self.remaining <= 0.0 {
            return BpAction::Hit;
        }
        BpAction::Assign(self.split_evenly())
    }

    pub fn remaining(&self) -> f64 {
        self.remaining
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Replay the Fig. 2.5 trace: target 15, three workers.
    #[test]
    fn figure_2_5_count_trace() {
        let mut bp = GlobalBreakpoint::count(1, 15, 3);
        let init = bp.initial_assignments();
        assert_eq!(init, vec![(0, 5.0), (1, 5.0), (2, 5.0)]);

        // t1: worker b (=1) reaches 5.
        assert_eq!(bp.on_target_reached(1, 5.0), BpAction::StartTimer);
        // t2: τ fires; inquire a and c.
        assert_eq!(bp.on_timeout(), BpAction::Inquire(vec![0, 2]));
        // t3: a reports 3, c reports 1. Remaining 15-5-3-1 = 6.
        assert_eq!(bp.on_inquiry_report(0, 3.0), BpAction::None);
        let act = bp.on_inquiry_report(2, 1.0);
        // t4: reassign 2 each.
        assert_eq!(act, BpAction::Assign(vec![(0, 2.0), (1, 2.0), (2, 2.0)]));
        assert_eq!(bp.remaining(), 6.0);

        // t5: worker c reaches 2.
        assert_eq!(bp.on_target_reached(2, 2.0), BpAction::StartTimer);
        // t6: τ fires; inquire a and b.
        assert_eq!(bp.on_timeout(), BpAction::Inquire(vec![0, 1]));
        // t7: a → 1, b → 1. Remaining 2.
        assert_eq!(bp.on_inquiry_report(0, 1.0), BpAction::None);
        let act = bp.on_inquiry_report(1, 1.0);
        // t8: assign 1 to a and b each (remaining 2 > threshold 1).
        assert_eq!(act, BpAction::Assign(vec![(0, 1.0), (1, 1.0)]));

        // t9: a reaches 1. Outstanding (b's 1.0) ≤ threshold → NO
        // inquiry (the paper's "reassigning this target to another
        // worker will not increase parallelism").
        assert_eq!(bp.on_target_reached(0, 1.0), BpAction::None);
        // t10: b reaches 1 → hit.
        assert_eq!(bp.on_target_reached(1, 1.0), BpAction::Hit);
    }

    #[test]
    fn all_reach_within_tau_hits_immediately() {
        let mut bp = GlobalBreakpoint::count(1, 9, 3);
        bp.initial_assignments();
        assert_eq!(bp.on_target_reached(0, 3.0), BpAction::StartTimer);
        assert_eq!(bp.on_target_reached(1, 3.0), BpAction::None);
        assert_eq!(bp.on_target_reached(2, 3.0), BpAction::Hit);
    }

    #[test]
    fn count_shares_are_integral_and_total() {
        let mut bp = GlobalBreakpoint::count(1, 14, 4);
        let init = bp.initial_assignments();
        let total: f64 = init.iter().map(|(_, a)| a).sum();
        assert_eq!(total, 14.0);
        for (_, a) in &init {
            assert_eq!(a.fract(), 0.0);
        }
    }

    #[test]
    fn sum_tail_goes_to_single_worker() {
        let mut bp = GlobalBreakpoint::sum(2, 90.0, 5, 3, 10.0);
        bp.initial_assignments(); // 30 each
        bp.on_target_reached(0, 31.0); // overshoot counts
        bp.on_timeout();
        bp.on_inquiry_report(1, 30.0);
        let act = bp.on_inquiry_report(2, 20.0);
        // Remaining 90-81 = 9 ≤ tail 10 → single worker.
        match act {
            BpAction::Assign(v) => {
                assert_eq!(v.len(), 1);
                assert!((v[0].1 - 9.0).abs() < 1e-9);
            }
            other => panic!("expected single assignment, got {other:?}"),
        }
    }

    #[test]
    fn sum_overshoot_hits() {
        let mut bp = GlobalBreakpoint::sum(2, 30.0, 0, 2, 5.0);
        bp.initial_assignments();
        assert_eq!(bp.on_target_reached(0, 16.0), BpAction::StartTimer);
        assert_eq!(bp.on_target_reached(1, 15.0), BpAction::Hit);
        assert!(bp.remaining() <= 0.0);
    }

    #[test]
    fn inquiry_with_zero_progress_reassigns() {
        let mut bp = GlobalBreakpoint::count(1, 12, 2);
        bp.initial_assignments();
        bp.on_target_reached(0, 6.0);
        assert_eq!(bp.on_timeout(), BpAction::Inquire(vec![1]));
        let act = bp.on_inquiry_report(1, 0.0);
        assert_eq!(act, BpAction::Assign(vec![(0, 3.0), (1, 3.0)]));
    }

    #[test]
    fn timeout_in_wrong_phase_is_noop() {
        let mut bp = GlobalBreakpoint::count(1, 10, 2);
        bp.initial_assignments();
        assert_eq!(bp.on_timeout(), BpAction::None);
    }
}

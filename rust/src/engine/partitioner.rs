//! Output partitioning: the data-transfer policy on each edge (§2.3.3)
//! plus the **mitigation overlay** Reshape installs at runtime (§3.3).
//!
//! Every sender worker owns one [`Partitioner`] per outgoing edge. The
//! base scheme (hash / range / round-robin / broadcast / one-to-one)
//! maps a tuple to a destination worker; the overlay then optionally
//! re-routes tuples bound for a *skewed* worker to its helper(s):
//!
//! * **Phase 1** (`CatchUpAll`/`CatchUpKeys`): all (or a key-subset of)
//!   future input of the skewed worker goes to the helper(s) so the
//!   helpers' queues catch up with the skewed worker's backlog (§3.3.2).
//! * **Phase 2 SBR** (`SplitRecords`): redirect `num` out of every
//!   `den` tuples to the helper — e.g. 9 of every 26 (§3.3.1). With
//!   multiple helpers the window is segmented: h₁ takes the first
//!   `num₁`, h₂ the next `num₂`, the skewed worker keeps the rest.
//! * **Phase 2 SBK** (`SplitKeys`): redirect a fixed key set.
//!
//! Routing uses only sender-local state (a per-overlay counter), so all
//! workers of the upstream operator apply the same route independently —
//! exactly how the paper's controller "changes the partitioning logic at
//! the previous operator" (Fig. 3.2(e,f)).
//!
//! ## Batch-granularity routing and the hash-column lifecycle
//!
//! The exchange hot path routes whole batches, not tuples.
//! [`Partitioner::route_batch`] consumes a **hash column** — the
//! partitioning key's [`Value::stable_hash`] per tuple, computed once
//! per batch by [`hash_column`] into a caller-owned scratch vector and
//! shared by every edge partitioning on the same field — and fills a
//! reusable [`RouteVec`] with per-destination **selection vectors**
//! (tuple indices in batch order) plus per-destination base counts for
//! the σ_w / natural-share gauges (§3.4.1). Overlay-free hash,
//! round-robin, range and one-to-one edges take column-at-a-time fast
//! paths; any installed overlay falls back to a per-tuple walk over the
//! same column so every stateful counter (round-robin cursor, catch-up
//! cursor, SBR windows) advances exactly as [`Partitioner::route_with_base`]
//! would — the two paths are property-tested equivalent under random
//! overlays, `set_route` epochs and `rescale` events. Batches whose
//! tuples all route to one destination are flagged `single`, letting
//! the sender ship the shared allocation as a zero-copy slice.
//!
//! With the columnar data plane, [`hash_column`] reads the typed key
//! column directly ([`crate::column::Column::hash_range`], byte-equal
//! to per-tuple hashing), and the exchange ships the finished column
//! downstream inside the message
//! ([`crate::engine::message::HashColumn`]) so receivers reuse it for
//! SBK gauges and keyed probes instead of re-hashing.

use crate::tuple::{value_cmp, Tuple, TupleBatch, Value};
use std::collections::HashMap;

/// Base partitioning scheme for an edge (chosen at plan time).
#[derive(Clone, Debug)]
pub enum PartitionScheme {
    /// Sender `i` → receiver `i` (same-machine one-to-one, §2.3.3(a)).
    OneToOne,
    /// Rotate over receivers (§2.3.3(b)).
    RoundRobin,
    /// Hash of field `key` mod receivers (§2.3.3(c)).
    Hash { key: usize },
    /// Range partition on field `key` with explicit upper bounds per
    /// receiver (receiver `i` takes values ≤ `bounds[i]`; the last
    /// receiver takes the rest). Used by sort (§3.5.4).
    Range { key: usize, bounds: Vec<Value> },
    /// Copy to every receiver (broadcast joins of heavy hitters).
    Broadcast,
}

/// How tuples routed to a skewed worker are shared with one helper.
#[derive(Clone, Debug, PartialEq)]
pub enum ShareMode {
    /// Phase 1: everything goes to the helper until revoked (§3.3.2).
    CatchUpAll,
    /// Phase 1 (restricted): only these keys (stable hashes) go to the
    /// helper — "send only a portion, such as the December data".
    CatchUpKeys(Vec<u64>),
    /// Phase 2 SBR: `num` of every `den` tuples go to the helper.
    SplitRecords { num: u32, den: u32 },
    /// SBR restricted to a key set: `num` of every `den` tuples *of
    /// these keys* go to the helper (Flow-Join's heavy-hitter split —
    /// other keys stay put because their state was never migrated).
    SplitRecordsKeys { keys: Vec<u64>, num: u32, den: u32 },
    /// Phase 2 SBK: tuples with these key hashes go to the helper.
    SplitKeys(Vec<u64>),
}

/// A mitigation route: tuples bound for `skewed` may be re-routed to
/// `helper` according to `mode`. One route per (skewed, helper) pair;
/// multiple helpers = multiple routes (§3.6.2).
#[derive(Clone, Debug)]
pub struct MitigationRoute {
    pub skewed: usize,
    pub helper: usize,
    pub mode: ShareMode,
    /// Monotone epoch; receivers see a `Marker` when routes change
    /// (mutable-state synchronization, §3.5.3).
    pub epoch: u64,
}

/// Merged overlay state for one skewed worker.
#[derive(Clone, Debug, Default)]
struct SkewOverlay {
    /// Phase-1 helpers (round-robin among them) and optional key filter.
    catch_up: Vec<usize>,
    catch_up_keys: Option<Vec<u64>>,
    catch_up_cursor: usize,
    /// SBK: key hash → helper.
    moved_keys: Vec<(u64, usize)>,
    /// SBR segments: (helper, num); the shared window length.
    sbr: Vec<(usize, u32)>,
    sbr_den: u32,
    sbr_counter: u64,
    /// Keyed SBR: (keys, helper, num, den, counter).
    keyed_sbr: Vec<(Vec<u64>, usize, u32, u32, u64)>,
}

impl SkewOverlay {
    fn is_empty(&self) -> bool {
        self.catch_up.is_empty()
            && self.moved_keys.is_empty()
            && self.sbr.is_empty()
            && self.keyed_sbr.is_empty()
    }
}

/// Binary search for the first bound ≥ v (perf: linear scan cost
/// 46 ns/tuple at 15 bounds → ~12 ns).
#[inline]
fn range_dest(v: &Value, bounds: &[Value], receivers: usize) -> usize {
    let mut lo = 0usize;
    let mut hi = bounds.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if value_cmp(v, &bounds[mid]) == std::cmp::Ordering::Greater {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo.min(receivers - 1)
}

/// Fill `out` with the stable-hash column of field `key` over `batch`:
/// one [`Value::stable_hash`] per tuple, in batch order. Computed once
/// per batch and reused by base routing, overlay key matching, and the
/// sender-maintained receiver gauges.
pub fn hash_column(batch: &TupleBatch, key: usize, out: &mut Vec<u64>) {
    out.clear();
    // Columnar fast path: hash the typed key vector in one tight loop
    // (byte-identical to per-tuple `stable_hash`, see
    // `Column::hash_range`). Row-only batches keep the per-tuple walk.
    if let Some(cv) = batch.columns() {
        if let Some(col) = cv.set.cols.get(key) {
            col.hash_range(cv.start, cv.end, out);
            return;
        }
    }
    out.reserve(batch.len());
    for t in batch.iter() {
        out.push(t.get(key).stable_hash());
    }
}

/// Per-destination selection vectors for one routed batch — the output
/// of [`Partitioner::route_batch`], reused across calls.
#[derive(Debug, Default)]
pub struct RouteVec {
    /// `sel[d]` = indices (into the routed batch) of tuples whose
    /// *final* destination is `d`, in batch order. Entries past the
    /// current receiver count are stale scratch and left empty.
    pub sel: Vec<Vec<u32>>,
    /// Tuples whose *base* destination (pre-overlay) is `d` — the
    /// natural-share gauge increment for receiver `d` (§3.3.2).
    pub base_counts: Vec<u32>,
    /// Set when every tuple routes to one destination (single-run
    /// batches ship as one zero-copy slice). `sel` may or may not be
    /// filled when this is set; `single` wins.
    pub single: Option<usize>,
    /// The scheme was `Broadcast`: destinations = all receivers.
    pub broadcast: bool,
}

impl RouteVec {
    fn reset(&mut self, receivers: usize) {
        if self.sel.len() < receivers {
            self.sel.resize_with(receivers, Vec::new);
        }
        for s in self.sel.iter_mut() {
            s.clear();
        }
        self.base_counts.clear();
        self.base_counts.resize(receivers, 0);
        self.single = None;
        self.broadcast = false;
    }

    /// Expand to one destination per tuple (tests / slow consumers).
    pub fn dests(&self, len: usize, receivers: usize) -> Vec<usize> {
        if let Some(d) = self.single {
            return vec![d; len];
        }
        let mut v = vec![usize::MAX; len];
        for (d, sel) in self.sel.iter().enumerate().take(receivers) {
            for &i in sel {
                v[i as usize] = d;
            }
        }
        v
    }
}

/// A partitioner for one outgoing edge: base scheme + mitigation
/// overlay + round-robin cursor.
pub struct Partitioner {
    pub scheme: PartitionScheme,
    pub receivers: usize,
    overlays: HashMap<usize, SkewOverlay>,
    /// Epoch of the most recent route change (for markers).
    pub epoch: u64,
    rr_cursor: usize,
    sender_idx: usize,
}

impl Partitioner {
    pub fn new(scheme: PartitionScheme, receivers: usize, sender_idx: usize) -> Partitioner {
        assert!(receivers > 0);
        Partitioner {
            scheme,
            receivers,
            overlays: HashMap::new(),
            epoch: 0,
            rr_cursor: sender_idx % receivers,
            sender_idx,
        }
    }

    /// The partitioning key of `t` under this scheme, as a stable hash
    /// (used by SBK key sets). Returns 0 for keyless schemes.
    pub fn key_hash(&self, t: &Tuple) -> u64 {
        match &self.scheme {
            PartitionScheme::Hash { key } | PartitionScheme::Range { key, .. } => {
                t.get(*key).stable_hash()
            }
            _ => 0,
        }
    }

    /// Base destination (before mitigation overlay). `Broadcast`
    /// returns `usize::MAX` as a sentinel meaning "all receivers".
    #[inline]
    pub fn base_route(&mut self, t: &Tuple) -> usize {
        match &self.scheme {
            PartitionScheme::OneToOne => self.sender_idx % self.receivers,
            PartitionScheme::RoundRobin => {
                let r = self.rr_cursor;
                self.rr_cursor = (self.rr_cursor + 1) % self.receivers;
                r
            }
            PartitionScheme::Hash { key } => {
                (t.get(*key).stable_hash() % self.receivers as u64) as usize
            }
            PartitionScheme::Range { key, bounds } => {
                range_dest(t.get(*key), bounds, self.receivers)
            }
            PartitionScheme::Broadcast => usize::MAX,
        }
    }

    /// Final destination after the mitigation overlay.
    #[inline]
    pub fn route(&mut self, t: &Tuple) -> usize {
        self.route_with_base(t).1
    }

    /// (base destination, final destination) — senders maintain both
    /// the σ_w and the natural-share gauges from one routing pass.
    ///
    /// The partitioning-key hash is computed at most once per tuple and
    /// reused for both the base route and the mitigation overlay (the
    /// pre-refactor code hashed twice on overlaid hash edges).
    #[inline]
    pub fn route_with_base(&mut self, t: &Tuple) -> (usize, usize) {
        if let PartitionScheme::Hash { key } = &self.scheme {
            let key = *key;
            let h = t.get(key).stable_hash();
            let base = (h % self.receivers as u64) as usize;
            let dest = self.overlay_route(base, h);
            return (base, dest);
        }
        let base = self.base_route(t);
        if base == usize::MAX || self.overlays.is_empty() {
            return (base, base);
        }
        let h = match &self.scheme {
            PartitionScheme::Range { key, .. } => t.get(*key).stable_hash(),
            _ => 0,
        };
        let dest = self.overlay_route(base, h);
        (base, dest)
    }

    #[inline]
    fn overlay_route(&mut self, base: usize, key: u64) -> usize {
        if base == usize::MAX || self.overlays.is_empty() {
            return base;
        }
        let Some(ov) = self.overlays.get_mut(&base) else {
            return base;
        };
        // Phase 1 takes precedence: helper must catch up first.
        if !ov.catch_up.is_empty() {
            let pass = match &ov.catch_up_keys {
                None => true,
                Some(keys) => keys.contains(&key),
            };
            if pass {
                let h = ov.catch_up[ov.catch_up_cursor % ov.catch_up.len()];
                ov.catch_up_cursor += 1;
                return h;
            }
        }
        // SBK moved keys.
        if let Some((_, h)) = ov.moved_keys.iter().find(|(k, _)| *k == key) {
            return *h;
        }
        // Keyed SBR (heavy-hitter record split).
        for (keys, h, num, den, counter) in ov.keyed_sbr.iter_mut() {
            if keys.contains(&key) {
                let c = (*counter % *den as u64) as u32;
                *counter += 1;
                if c < *num {
                    return *h;
                }
                return base;
            }
        }
        // SBR window segments.
        if !ov.sbr.is_empty() && ov.sbr_den > 0 {
            let c = (ov.sbr_counter % ov.sbr_den as u64) as u32;
            ov.sbr_counter += 1;
            let mut cum = 0u32;
            for (h, num) in &ov.sbr {
                cum += num;
                if c < cum {
                    return *h;
                }
            }
        }
        base
    }

    /// Field index the partitioning key lives in, for keyed schemes.
    pub fn key_field(&self) -> Option<usize> {
        match &self.scheme {
            PartitionScheme::Hash { key } | PartitionScheme::Range { key, .. } => Some(*key),
            _ => None,
        }
    }

    /// Whether [`Partitioner::route_batch`] reads the hash column:
    /// always on hash edges (the base route is `h % n`); on range edges
    /// only while an overlay is installed (overlay key sets match on
    /// stable hashes); never for keyless schemes.
    pub fn needs_hashes(&self) -> bool {
        match &self.scheme {
            // A single receiver with no overlay routes everything to 0;
            // no column needed (the common 1-worker sink/aggregate edge
            // should not pay one hash per tuple).
            PartitionScheme::Hash { .. } => {
                self.receivers > 1 || !self.overlays.is_empty()
            }
            PartitionScheme::Range { .. } => !self.overlays.is_empty(),
            _ => false,
        }
    }

    /// Vectorized scatter: route a whole batch into per-destination
    /// selection vectors. `hashes` must be the [`hash_column`] of this
    /// partitioner's [`Partitioner::key_field`] over `batch` whenever
    /// [`Partitioner::needs_hashes`] is true; it is ignored otherwise.
    ///
    /// Destinations (and every stateful counter: round-robin cursor,
    /// catch-up cursor, SBR windows) are exactly those of a per-tuple
    /// [`Partitioner::route_with_base`] loop over the same batch;
    /// overlay-free schemes take column-at-a-time fast paths, overlays
    /// fall back to the shared per-tuple overlay walk.
    pub fn route_batch(&mut self, batch: &TupleBatch, hashes: &[u64], out: &mut RouteVec) {
        let n = batch.len();
        out.reset(self.receivers);
        if matches!(self.scheme, PartitionScheme::Broadcast) {
            out.broadcast = true;
            return;
        }
        if n == 0 {
            return;
        }
        debug_assert!(!self.needs_hashes() || hashes.len() == n);
        // One receiver, no overlay: every scheme routes everything to
        // 0 — single-run without touching the hash column.
        if self.receivers == 1 && self.overlays.is_empty() {
            out.base_counts[0] = n as u32;
            out.single = Some(0);
            return;
        }
        if self.overlays.is_empty() {
            match &self.scheme {
                PartitionScheme::OneToOne => {
                    let d = self.sender_idx % self.receivers;
                    out.base_counts[d] = n as u32;
                    out.single = Some(d);
                }
                PartitionScheme::RoundRobin => {
                    for i in 0..n {
                        let d = self.rr_cursor;
                        self.rr_cursor = (self.rr_cursor + 1) % self.receivers;
                        out.sel[d].push(i as u32);
                        out.base_counts[d] += 1;
                    }
                }
                PartitionScheme::Hash { .. } => {
                    // Index 0..n (not hashes.iter()): a too-short hash
                    // column must panic, never silently drop the tail.
                    let m = self.receivers as u64;
                    let first = (hashes[0] % m) as usize;
                    // Uniform-prefix scan: a hot-key batch (the common
                    // skewed case) pays one modulo-compare per tuple
                    // and never materializes selection vectors.
                    let mut split = n;
                    for i in 1..n {
                        if (hashes[i] % m) as usize != first {
                            split = i;
                            break;
                        }
                    }
                    if split == n {
                        out.base_counts[first] = n as u32;
                        out.single = Some(first);
                        return;
                    }
                    // Mixed batch: backfill the uniform prefix, then
                    // scatter the rest.
                    out.sel[first].reserve(split);
                    for i in 0..split {
                        out.sel[first].push(i as u32);
                    }
                    out.base_counts[first] = split as u32;
                    for i in split..n {
                        let d = (hashes[i] % m) as usize;
                        out.sel[d].push(i as u32);
                        out.base_counts[d] += 1;
                    }
                }
                PartitionScheme::Range { key, bounds } => {
                    let key = *key;
                    let first = range_dest(batch.get(0).get(key), bounds, self.receivers);
                    let mut uniform = true;
                    for i in 0..n {
                        let d = range_dest(batch.get(i).get(key), bounds, self.receivers);
                        uniform &= d == first;
                        out.sel[d].push(i as u32);
                        out.base_counts[d] += 1;
                    }
                    if uniform {
                        out.single = Some(first);
                    }
                }
                PartitionScheme::Broadcast => unreachable!(),
            }
            return;
        }
        // Overlay path: per-tuple over the shared hash column, so every
        // stateful counter advances exactly as route_with_base would.
        let keyed_hash = matches!(self.scheme, PartitionScheme::Hash { .. });
        let keyed_range = matches!(self.scheme, PartitionScheme::Range { .. });
        let mut first = usize::MAX;
        let mut uniform = true;
        for i in 0..n {
            let (base, h) = if keyed_hash {
                let h = hashes[i];
                ((h % self.receivers as u64) as usize, h)
            } else if keyed_range {
                (self.base_route(batch.get(i)), hashes[i])
            } else {
                (self.base_route(batch.get(i)), 0)
            };
            let dest = self.overlay_route(base, h);
            if i == 0 {
                first = dest;
            }
            uniform &= dest == first;
            out.sel[dest].push(i as u32);
            out.base_counts[base] += 1;
        }
        if uniform {
            out.single = Some(first);
        }
    }

    /// Re-target this partitioner at a resized receiver set (elastic
    /// scaling). Every mitigation overlay is dropped: overlay routes
    /// reference receiver indices of the *old* set, and on hash edges
    /// the base destinations themselves move, so any surviving overlay
    /// would mis-route relative to the freshly re-hashed operator
    /// state. Reshape re-detects skew against the new worker set.
    /// `bounds` replaces the range-bound vector when the scheme is
    /// `Range` (the coordinator recomputes them); `None` keeps it.
    /// `Broadcast` edges rescale too (universal elasticity): the
    /// sentinel semantics are unchanged and the new receiver count
    /// simply widens/narrows the fan-out set the sender flushes to.
    ///
    /// Semantically equivalent to the worker's `RescaleEdge` handler,
    /// which rebuilds the whole output edge (sender set and buffers
    /// change size) and therefore constructs a fresh partitioner; this
    /// in-place form serves embedders that own a bare partitioner and
    /// the scale-event property tests.
    pub fn rescale(&mut self, receivers: usize, bounds: Option<Vec<Value>>) {
        assert!(receivers > 0);
        self.receivers = receivers;
        self.overlays.clear();
        self.rr_cursor = self.sender_idx % receivers;
        self.epoch += 1;
        if let (PartitionScheme::Range { bounds: b, .. }, Some(nb)) =
            (&mut self.scheme, bounds)
        {
            *b = nb;
        }
    }

    /// Install or replace the route for (skewed → helper); merges with
    /// existing routes for the same skewed worker.
    ///
    /// Routes whose endpoints fall outside the current receiver set are
    /// ignored: a delayed `UpdateRoute` can land *after* a scale event
    /// shrank the operator, and applying it would route tuples to a
    /// retired worker (out-of-bounds sender index).
    pub fn set_route(&mut self, route: MitigationRoute) {
        if route.skewed >= self.receivers || route.helper >= self.receivers {
            return;
        }
        self.epoch = self.epoch.max(route.epoch);
        let ov = self.overlays.entry(route.skewed).or_default();
        match route.mode {
            ShareMode::CatchUpAll => {
                if !ov.catch_up.contains(&route.helper) {
                    ov.catch_up.push(route.helper);
                }
                ov.catch_up_keys = None;
            }
            ShareMode::CatchUpKeys(keys) => {
                if !ov.catch_up.contains(&route.helper) {
                    ov.catch_up.push(route.helper);
                }
                ov.catch_up_keys = Some(keys);
            }
            ShareMode::SplitRecords { num, den } => {
                // End any phase-1 redirection for this helper.
                ov.catch_up.retain(|h| *h != route.helper);
                if ov.sbr_den != den {
                    // New window length: restart segments.
                    ov.sbr.clear();
                    ov.sbr_den = den;
                    ov.sbr_counter = 0;
                }
                if let Some(seg) = ov.sbr.iter_mut().find(|(h, _)| *h == route.helper) {
                    seg.1 = num;
                } else {
                    ov.sbr.push((route.helper, num));
                }
            }
            ShareMode::SplitRecordsKeys { keys, num, den } => {
                ov.catch_up.retain(|h| *h != route.helper);
                ov.keyed_sbr.retain(|(_, h, ..)| *h != route.helper);
                ov.keyed_sbr.push((keys, route.helper, num, den, 0));
            }
            ShareMode::SplitKeys(keys) => {
                ov.catch_up.retain(|h| *h != route.helper);
                ov.moved_keys.retain(|(_, h)| *h != route.helper);
                for k in keys {
                    ov.moved_keys.push((k, route.helper));
                }
            }
        }
    }

    /// Remove every piece of the (skewed → helper) route, e.g. when
    /// phase 1 ends or mitigation is cancelled.
    pub fn clear_route(&mut self, skewed: usize, helper: usize) {
        if let Some(ov) = self.overlays.get_mut(&skewed) {
            ov.catch_up.retain(|h| *h != helper);
            ov.moved_keys.retain(|(_, h)| *h != helper);
            ov.sbr.retain(|(h, _)| *h != helper);
            ov.keyed_sbr.retain(|(_, h, ..)| *h != helper);
            if ov.is_empty() {
                self.overlays.remove(&skewed);
            }
        }
    }

    /// Number of skewed workers with an active overlay.
    pub fn active_overlays(&self) -> usize {
        self.overlays.len()
    }
}

/// Compute equal-width range bounds for `n` receivers over `[lo, hi]`
/// (floats). The deliberate mismatch between equal-width ranges and a
/// bell-shaped value distribution is what skews the sort workload W3.
pub fn equal_width_bounds(lo: f64, hi: f64, n: usize) -> Vec<Value> {
    assert!(n > 0);
    (1..n)
        .map(|i| Value::Float(lo + (hi - lo) * i as f64 / n as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::{Tuple, Value};

    fn t_int(k: i64) -> Tuple {
        Tuple::new(vec![Value::Int(k)])
    }

    /// First key in 0..limit that hashes to `target` of `n` receivers.
    fn key_for(target: usize, n: usize) -> i64 {
        (0..10_000)
            .find(|&k| {
                (Value::Int(k).stable_hash() % n as u64) as usize == target
            })
            .unwrap()
    }

    #[test]
    fn hash_deterministic_and_in_range() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        for k in 0..100 {
            let r1 = p.route(&t_int(k));
            let r2 = p.route(&t_int(k));
            assert_eq!(r1, r2);
            assert!(r1 < 4);
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = Partitioner::new(PartitionScheme::RoundRobin, 3, 0);
        let seq: Vec<usize> = (0..6).map(|_| p.route(&t_int(0))).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn one_to_one_uses_sender_index() {
        let mut p = Partitioner::new(PartitionScheme::OneToOne, 4, 2);
        assert_eq!(p.route(&t_int(5)), 2);
    }

    #[test]
    fn range_routes_by_bounds() {
        let mut p = Partitioner::new(
            PartitionScheme::Range {
                key: 0,
                bounds: vec![Value::Int(10), Value::Int(20)],
            },
            3,
            0,
        );
        assert_eq!(p.route(&t_int(5)), 0);
        assert_eq!(p.route(&t_int(10)), 0);
        assert_eq!(p.route(&t_int(15)), 1);
        assert_eq!(p.route(&t_int(999)), 2);
    }

    #[test]
    fn catch_up_all_redirects_everything() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let k = key_for(1, 4);
        p.set_route(MitigationRoute {
            skewed: 1,
            helper: 3,
            mode: ShareMode::CatchUpAll,
            epoch: 1,
        });
        assert_eq!(p.route(&t_int(k)), 3);
        // Other workers' tuples unaffected.
        let k0 = key_for(0, 4);
        assert_eq!(p.route(&t_int(k0)), 0);
    }

    #[test]
    fn catch_up_keys_filters() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let ka = key_for(1, 4);
        // Another key on worker 1.
        let kb = (ka + 1..10_000).find(|&k| {
            (Value::Int(k).stable_hash() % 4) as usize == 1
        })
        .unwrap();
        p.set_route(MitigationRoute {
            skewed: 1,
            helper: 2,
            mode: ShareMode::CatchUpKeys(vec![Value::Int(ka).stable_hash()]),
            epoch: 1,
        });
        assert_eq!(p.route(&t_int(ka)), 2);
        assert_eq!(p.route(&t_int(kb)), 1);
    }

    #[test]
    fn sbr_splits_exactly_num_of_den() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0);
        let k = key_for(0, 2);
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 1,
            mode: ShareMode::SplitRecords { num: 9, den: 26 },
            epoch: 1,
        });
        let mut to_helper = 0;
        for _ in 0..2600 {
            if p.route(&t_int(k)) == 1 {
                to_helper += 1;
            }
        }
        assert_eq!(to_helper, 900); // exactly 9 of every 26
    }

    #[test]
    fn sbr_two_helpers_segment_window() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let k = key_for(0, 4);
        for (h, num) in [(1usize, 3u32), (2usize, 2u32)] {
            p.set_route(MitigationRoute {
                skewed: 0,
                helper: h,
                mode: ShareMode::SplitRecords { num, den: 9 },
                epoch: 1,
            });
        }
        let mut counts = [0usize; 4];
        for _ in 0..900 {
            counts[p.route(&t_int(k))] += 1;
        }
        assert_eq!(counts[1], 300); // 3 of 9
        assert_eq!(counts[2], 200); // 2 of 9
        assert_eq!(counts[0], 400); // skewed keeps 4 of 9
    }

    #[test]
    fn sbk_moves_only_listed_keys() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0);
        let ka = key_for(0, 2);
        let kb = (ka + 1..10_000).find(|&k| {
            (Value::Int(k).stable_hash() % 2) as usize == 0
        })
        .unwrap();
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 1,
            mode: ShareMode::SplitKeys(vec![Value::Int(ka).stable_hash()]),
            epoch: 1,
        });
        assert_eq!(p.route(&t_int(ka)), 1);
        assert_eq!(p.route(&t_int(kb)), 0);
    }

    #[test]
    fn clear_route_restores_base() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0);
        let k = key_for(0, 2);
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 1,
            mode: ShareMode::CatchUpAll,
            epoch: 1,
        });
        assert_eq!(p.route(&t_int(k)), 1);
        p.clear_route(0, 1);
        assert_eq!(p.route(&t_int(k)), 0);
        assert_eq!(p.active_overlays(), 0);
    }

    #[test]
    fn phase1_to_phase2_transition() {
        // Installing SplitRecords for the same helper ends its catch-up.
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0);
        let k = key_for(0, 2);
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 1,
            mode: ShareMode::CatchUpAll,
            epoch: 1,
        });
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 1,
            mode: ShareMode::SplitRecords { num: 1, den: 2 },
            epoch: 2,
        });
        let routes: Vec<usize> = (0..4).map(|_| p.route(&t_int(k))).collect();
        assert_eq!(routes, vec![1, 0, 1, 0]);
    }

    #[test]
    fn broadcast_sentinel() {
        let mut p = Partitioner::new(PartitionScheme::Broadcast, 3, 0);
        assert_eq!(p.route(&t_int(1)), usize::MAX);
    }

    #[test]
    fn equal_width_bounds_count() {
        let b = equal_width_bounds(0.0, 100.0, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0], Value::Float(25.0));
    }

    #[test]
    fn rescale_clears_overlays_and_stays_in_range() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        p.set_route(MitigationRoute {
            skewed: 1,
            helper: 3,
            mode: ShareMode::CatchUpAll,
            epoch: 1,
        });
        assert_eq!(p.active_overlays(), 1);
        p.rescale(2, None);
        assert_eq!(p.active_overlays(), 0);
        for k in 0..200 {
            assert!(p.route(&t_int(k)) < 2);
        }
    }

    #[test]
    fn rescale_replaces_range_bounds() {
        let mut p = Partitioner::new(
            PartitionScheme::Range { key: 0, bounds: vec![Value::Int(10)] },
            2,
            0,
        );
        p.rescale(4, Some(vec![Value::Int(5), Value::Int(10), Value::Int(15)]));
        assert_eq!(p.route(&t_int(3)), 0);
        assert_eq!(p.route(&t_int(8)), 1);
        assert_eq!(p.route(&t_int(12)), 2);
        assert_eq!(p.route(&t_int(99)), 3);
    }

    #[test]
    fn rescale_broadcast_keeps_sentinel_and_widens_fanout() {
        let mut p = Partitioner::new(PartitionScheme::Broadcast, 2, 0);
        p.rescale(5, None);
        assert_eq!(p.receivers, 5);
        assert_eq!(p.route(&t_int(1)), usize::MAX);
        let mut rv = RouteVec::default();
        p.route_batch(&batch_of(&[1, 2, 3]), &[], &mut rv);
        assert!(rv.broadcast);
        p.rescale(1, None);
        assert_eq!(p.receivers, 1);
        assert_eq!(p.route(&t_int(1)), usize::MAX);
    }

    #[test]
    fn stale_out_of_range_route_is_ignored() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 2, 0);
        // A delayed route for a 4-worker epoch arrives after 4→2.
        p.set_route(MitigationRoute {
            skewed: 0,
            helper: 3,
            mode: ShareMode::CatchUpAll,
            epoch: 7,
        });
        assert_eq!(p.active_overlays(), 0);
        for k in 0..100 {
            assert!(p.route(&t_int(k)) < 2);
        }
    }

    fn batch_of(keys: &[i64]) -> crate::tuple::TupleBatch {
        keys.iter().map(|&k| t_int(k)).collect()
    }

    /// Route a batch per-tuple through `p` and return (dests, base
    /// counts) — the reference the vectorized path must match.
    fn per_tuple_reference(p: &mut Partitioner, keys: &[i64]) -> (Vec<usize>, Vec<u32>) {
        let mut dests = Vec::with_capacity(keys.len());
        let mut bases = vec![0u32; p.receivers];
        for &k in keys {
            let (b, d) = p.route_with_base(&t_int(k));
            dests.push(d);
            bases[b] += 1;
        }
        (dests, bases)
    }

    fn route_batch_of(p: &mut Partitioner, keys: &[i64]) -> RouteVec {
        let batch = batch_of(keys);
        let mut hashes = Vec::new();
        if p.needs_hashes() {
            hash_column(&batch, 0, &mut hashes);
        }
        let mut rv = RouteVec::default();
        p.route_batch(&batch, &hashes, &mut rv);
        rv
    }

    #[test]
    fn route_batch_matches_per_tuple_hash_no_overlay() {
        let keys: Vec<i64> = (0..100).collect();
        let mut pt = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let mut pb = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let (dests, bases) = per_tuple_reference(&mut pt, &keys);
        let rv = route_batch_of(&mut pb, &keys);
        assert_eq!(rv.dests(keys.len(), 4), dests);
        assert_eq!(rv.base_counts, bases);
        assert!(rv.single.is_none());
    }

    #[test]
    fn route_batch_matches_per_tuple_under_overlays() {
        let keys: Vec<i64> = (0..300).map(|i| i % 17).collect();
        let mk = || Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let mut pt = mk();
        let mut pb = mk();
        for p in [&mut pt, &mut pb] {
            p.set_route(MitigationRoute {
                skewed: 1,
                helper: 3,
                mode: ShareMode::SplitRecords { num: 2, den: 5 },
                epoch: 1,
            });
            p.set_route(MitigationRoute {
                skewed: 0,
                helper: 2,
                mode: ShareMode::CatchUpAll,
                epoch: 2,
            });
        }
        // Two consecutive batches: stateful SBR windows must stay in
        // phase across route_batch calls.
        for chunk in keys.chunks(150) {
            let (dests, bases) = per_tuple_reference(&mut pt, chunk);
            let rv = route_batch_of(&mut pb, chunk);
            assert_eq!(rv.dests(chunk.len(), 4), dests);
            assert_eq!(rv.base_counts, bases);
        }
    }

    #[test]
    fn route_batch_round_robin_cursor_stays_in_phase() {
        let mut pt = Partitioner::new(PartitionScheme::RoundRobin, 3, 0);
        let mut pb = Partitioner::new(PartitionScheme::RoundRobin, 3, 0);
        for len in [4usize, 5, 1, 7] {
            let keys: Vec<i64> = vec![0; len];
            let (dests, bases) = per_tuple_reference(&mut pt, &keys);
            let rv = route_batch_of(&mut pb, &keys);
            assert_eq!(rv.dests(len, 3), dests);
            assert_eq!(rv.base_counts, bases);
        }
    }

    #[test]
    fn route_batch_single_run_detection() {
        // One-to-one: structurally single-run.
        let mut p = Partitioner::new(PartitionScheme::OneToOne, 4, 2);
        let rv = route_batch_of(&mut p, &[1, 2, 3]);
        assert_eq!(rv.single, Some(2));
        assert_eq!(rv.base_counts[2], 3);
        // Hash: a batch of one repeated key is detected as single-run.
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 4, 0);
        let k = key_for(1, 4);
        let rv = route_batch_of(&mut p, &[k, k, k, k]);
        assert_eq!(rv.single, Some(1));
        // Mixed keys are not.
        let k0 = key_for(0, 4);
        let rv = route_batch_of(&mut p, &[k, k0]);
        assert!(rv.single.is_none());
    }

    #[test]
    fn route_batch_broadcast_flag() {
        let mut p = Partitioner::new(PartitionScheme::Broadcast, 3, 0);
        let rv = route_batch_of(&mut p, &[1, 2]);
        assert!(rv.broadcast);
        assert!(rv.single.is_none());
    }

    #[test]
    fn route_batch_range_matches_per_tuple() {
        let mk = || {
            Partitioner::new(
                PartitionScheme::Range {
                    key: 0,
                    bounds: vec![Value::Int(10), Value::Int(20)],
                },
                3,
                0,
            )
        };
        let keys: Vec<i64> = vec![5, 10, 15, 25, 7, 999, 11];
        let mut pt = mk();
        let mut pb = mk();
        let (dests, bases) = per_tuple_reference(&mut pt, &keys);
        let rv = route_batch_of(&mut pb, &keys);
        assert_eq!(rv.dests(keys.len(), 3), dests);
        assert_eq!(rv.base_counts, bases);
    }

    #[test]
    fn overlays_for_two_skewed_workers_coexist() {
        let mut p = Partitioner::new(PartitionScheme::Hash { key: 0 }, 8, 0);
        for (s, h) in [(0usize, 4usize), (1, 5)] {
            p.set_route(MitigationRoute {
                skewed: s,
                helper: h,
                mode: ShareMode::CatchUpAll,
                epoch: 1,
            });
        }
        assert_eq!(p.active_overlays(), 2);
        let k0 = key_for(0, 8);
        let k1 = key_for(1, 8);
        assert_eq!(p.route(&t_int(k0)), 4);
        assert_eq!(p.route(&t_int(k1)), 5);
    }
}

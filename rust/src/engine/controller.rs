//! The coordinator: the paper's controller + principal actors, colocated
//! as one unit (fault-tolerance assumption A1, §2.6.2).
//!
//! [`Execution::start`] translates the operator DAG into the actor DAG
//! (§2.3.2): one thread per worker, bounded FIFO data channels along
//! every edge, a control inbox per worker, and an event channel back to
//! the coordinator thread. The driver interacts through blocking
//! methods (`pause`, `resume`, `stats`, breakpoints, checkpoint, crash,
//! `join`) that post [`Command`]s to the coordinator loop.
//!
//! Pluggable policies ([`CoordPlugin`]) run inside the coordinator loop
//! with periodic ticks and event callbacks — Reshape (Ch. 3) is such a
//! plugin; Maestro (Ch. 4) drives executions from outside through the
//! region-activation commands (`start_sources`, `await_ops`).

use crate::config::Config;
use crate::engine::breakpoint::{BpAction, GlobalBreakpoint};
use crate::engine::channel::{mailbox, ControlInbox, DataSender, Mailbox, WorkerGauges};
use crate::engine::dag::{Edge, OpSpec, Workflow};
use crate::engine::migrate::{MigrationOutcome, MigrationStep, PlanDelta, StepOutcome};
use crate::engine::fault::{Checkpoint, ExecError, LogRecord, ReplayLog, WorkerSnapshot};
use crate::engine::message::{
    BreakpointTarget, ControlMessage, DataEvent, DataMessage, LocalPredicate, WorkerEvent,
    WorkerId, WorkerStats,
};
use crate::engine::operator::{OpPatch, OpState};
use crate::engine::partitioner::{PartitionScheme, Partitioner};
use crate::engine::worker::{run_worker, OutputEdge, WorkerContext};
use crate::metrics::SupervisionStats;
use crate::tuple::Tuple;
use crate::workloads::{redistribute_sources, TupleSource};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Commands from the driver thread to the coordinator.
pub enum Command {
    Pause { reply: Sender<Duration> },
    Resume { reply: Sender<()> },
    Stats { reply: Sender<Vec<(WorkerId, WorkerStats)>> },
    SetLocalBp { op: usize, pred: Option<LocalPredicate>, reply: Sender<()> },
    SetCountBp { op: usize, total: u64, reply: Sender<u64> },
    SetSumBp { op: usize, total: f64, field: usize, tail: f64, reply: Sender<u64> },
    AwaitBpHit { reply: Sender<BpHit> },
    Modify { op: usize, patch: OpPatch, reply: Sender<()> },
    TakeCheckpoint { reply: Sender<Checkpoint> },
    TakeReplayLog { reply: Sender<Vec<LogRecord>> },
    CrashWorkers { workers: Vec<WorkerId> },
    StartSources { ops: Vec<usize>, reply: Sender<()> },
    AwaitOps { ops: Vec<usize>, reply: Sender<()> },
    AwaitPort { op: usize, port: usize, reply: Sender<()> },
    AwaitDone { reply: Sender<ExecSummary> },
    SendControl { to: WorkerId, msg: ControlMessage },
    TrackKeys { op: usize, on: bool },
    /// Elastic scaling (engine::scale): change `op`'s parallelism to
    /// `new_workers` inside one fenced epoch. Replies with the fence
    /// duration (zero if the request was refused — see the `do_scale`
    /// guards).
    Scale { op: usize, new_workers: usize, reply: Sender<Duration> },
    /// Live plan migration (engine::migrate): apply a structural plan
    /// delta as an ordered sequence of fenced steps, rolling the
    /// already-applied prefix back if a later step's fence refuses or
    /// cannot close. Replies with the per-step outcome trail.
    Migrate { delta: PlanDelta, reply: Sender<MigrationOutcome> },
    Shutdown,
}

/// A breakpoint hit notification.
#[derive(Clone, Debug)]
pub struct BpHit {
    pub id: u64,
    /// The culprit tuple for local breakpoints.
    pub tuple: Option<Tuple>,
    /// Time from breakpoint registration to hit.
    pub elapsed: Duration,
    /// For global breakpoints: amount produced beyond the target
    /// (§2.5.3's SUM overshoot; exactly 0 for COUNT).
    pub overshoot: f64,
}

/// Final execution summary.
#[derive(Clone, Debug, Default)]
pub struct ExecSummary {
    pub elapsed: Duration,
    /// (op, worker) → final stats.
    pub worker_stats: Vec<(WorkerId, WorkerStats)>,
    /// First-output instant per operator, relative to start (seconds).
    pub first_output: HashMap<usize, f64>,
    /// Total tuples produced by each operator.
    pub produced_by_op: HashMap<usize, u64>,
    /// Supervision counters: failures detected (and how), recovery
    /// cycles and their cost, automatic checkpoint cadence/sizes.
    pub supervision: SupervisionStats,
    /// Out-of-core counters: bytes spilled/read back, partitions,
    /// recursion depth, budget high-water
    /// ([`crate::engine::spill`]).
    pub spill: crate::metrics::SpillStats,
    /// Structured abnormal-termination cause. `None` for a clean run;
    /// `Some` when supervision aborted the execution (recovery
    /// unavailable or exhausted) — the run still terminated cleanly
    /// (workers joined, waiters released) rather than hanging.
    pub error: Option<ExecError>,
}

impl ExecSummary {
    /// Total input received by a worker, per the σ_w routed-input gauge.
    pub fn produced(&self, op: usize) -> u64 {
        self.produced_by_op.get(&op).copied().unwrap_or(0)
    }
}

/// Interface the coordinator exposes to plugins (Reshape, autoscale).
pub struct PluginCtx<'a> {
    pub workflow: &'a Workflow,
    pub gauges: &'a HashMap<WorkerId, Arc<WorkerGauges>>,
    pub controls: &'a HashMap<WorkerId, Arc<ControlInbox>>,
    pub config: &'a Config,
    pub started: Instant,
    /// Workers that have completed (skew tests skip them).
    pub completed: &'a HashSet<WorkerId>,
    /// Elastic-scaling requests queued by the plugin; the coordinator
    /// drains and executes them after the plugin callback returns.
    scale_requests: &'a RefCell<Vec<(usize, usize)>>,
}

impl<'a> PluginCtx<'a> {
    /// Send a control message (with the configured artificial delay).
    pub fn send_control(&self, to: WorkerId, msg: ControlMessage) {
        if let Some(inbox) = self.controls.get(&to) {
            inbox.send(msg, Duration::from_millis(self.config.ctrl_delay_ms));
        }
    }

    /// Broadcast a control message to all workers of `op`.
    pub fn broadcast(&self, op: usize, msg: ControlMessage) {
        for idx in 0..self.workflow.ops[op].workers {
            self.send_control(WorkerId::new(op, idx), msg.clone());
        }
    }

    /// Upstream operators feeding `op` (any port).
    pub fn upstream_ops(&self, op: usize) -> Vec<usize> {
        self.workflow
            .in_edges(op)
            .iter()
            .map(|e| e.from)
            .collect()
    }

    pub fn gauges_of(&self, id: WorkerId) -> Option<&Arc<WorkerGauges>> {
        self.gauges.get(&id)
    }

    pub fn workers_of(&self, op: usize) -> usize {
        self.workflow.ops[op].workers
    }

    /// Queue an elastic-scaling request: set `op`'s parallelism to
    /// `new_workers`. Executed by the coordinator (one fenced epoch per
    /// request) after the current plugin callback returns.
    pub fn request_scale(&self, op: usize, new_workers: usize) {
        self.scale_requests.borrow_mut().push((op, new_workers));
    }
}

/// A coordinator plugin: ticked periodically, sees worker events.
pub trait CoordPlugin: Send {
    fn name(&self) -> &str;
    /// Called every `period()`.
    fn tick(&mut self, ctx: &PluginCtx);
    /// Called on every worker event.
    fn on_event(&mut self, ev: &WorkerEvent, ctx: &PluginCtx);
    fn period(&self) -> Duration;
}

enum CoordMsg {
    Cmd(Command),
    Event(WorkerEvent),
}

/// A running workflow execution.
pub struct Execution {
    cmd_tx: Sender<CoordMsg>,
    coord: Option<JoinHandle<()>>,
    started: Instant,
    /// The execution's shared out-of-core context. Held here (as well
    /// as by the coordinator and every worker) so live spill stats are
    /// readable without a coordinator round-trip; the spill directory
    /// is removed when the last clone drops — i.e. after `Drop` has
    /// shut down and joined the coordinator (which joins the workers),
    /// on *every* teardown path.
    spill: crate::engine::spill::SpillCtx,
}

struct WorkerHandle {
    control: Arc<ControlInbox>,
    gauges: Arc<WorkerGauges>,
    thread: Option<JoinHandle<()>>,
}

/// Everything one worker hands back during a scale fence's unplug step
/// (`WorkerEvent::ScaleState`).
struct ScaleSurrender {
    state: OpState,
    pending: Vec<DataEvent>,
    /// The live scan range, for source workers (repartitioned over the
    /// new worker set).
    source: Option<Box<dyn TupleSource>>,
}

/// A materialization spliced onto a live edge mid-run
/// (`PlanDelta::InsertMat`): the writer/reader operator pair, the
/// shared store, and the original edge they replaced — everything
/// needed to undo the splice on `PlanDelta::RemoveMat`.
#[derive(Clone)]
struct LiveMat {
    from: usize,
    to: usize,
    to_port: usize,
    writer: usize,
    reader: usize,
    store: crate::maestro::materialize::MatStore,
}

/// Who scaled an operator first: the engine's ownership/veto guard
/// against the `AutoscalePlugin` and an external driver (Maestro's
/// re-planner, tests) issuing conflicting parallelism changes for the
/// same operator. The first party whose scale is *accepted* owns the
/// operator; the other party's later requests are refused outright
/// instead of silently last-writer-winning.
#[derive(Clone, Copy, PartialEq, Eq)]
enum ScaleOwner {
    Driver,
    Plugin,
}

struct Coordinator {
    workflow: Workflow,
    config: Config,
    handles: HashMap<WorkerId, WorkerHandle>,
    rx: Receiver<CoordMsg>,
    started: Instant,

    // Elastic scaling (engine::scale): the coordinator retains every
    // worker's data sender and the event-channel template so it can
    // spawn workers and re-inject surrendered input mid-run.
    senders: HashMap<WorkerId, DataSender>,
    ev_tx: Sender<WorkerEvent>,
    /// States + pending input (+ sources) collected from the scaled
    /// operator's old workers during a fence (keyed by worker).
    scale_collect: HashMap<WorkerId, ScaleSurrender>,
    /// Commands that arrived mid-fence, replayed after it closes.
    deferred: Vec<Command>,
    /// Scale requests queued by the coordinator plugin.
    scale_requests: RefCell<Vec<(usize, usize)>>,
    /// Monotone worker-set version, bumped per scale fence; stamped
    /// into `RescaleSelf` and spawned workers (the scatter-merge peer
    /// barrier is keyed on it).
    fence_epoch: u64,
    /// Ownership/veto guard: who scaled each operator first.
    scale_owner: HashMap<usize, ScaleOwner>,
    /// Whether sources were deployed auto-starting (false = Maestro
    /// dormant deployment), and which dormant source ops have since
    /// been started — workers spawned by a *source* scale must inherit
    /// the operator's current started/dormant status.
    sources_autostart: bool,
    started_sources: HashSet<usize>,

    // Live plan migration (engine::migrate).
    /// Materializations spliced onto live edges mid-run.
    live_mats: Vec<LiveMat>,
    /// Ops whose sources must stay dormant regardless of autostart
    /// status: mat readers wait for their writer to finish
    /// (`MatSource` reports EOF at the store's *current* end, so an
    /// early start would truncate the stream).
    dormant_ops: HashSet<usize>,
    /// writer op → reader op: when the last writer worker completes,
    /// the paired dormant reader is started.
    pending_mat_activations: HashMap<usize, usize>,

    // Pause bookkeeping.
    pause_outstanding: HashSet<WorkerId>,
    pause_reply: Option<(Sender<Duration>, Instant)>,
    /// The driver explicitly paused the workflow (a scale fence must
    /// not resume it on exit).
    user_paused: bool,

    // Completion.
    completed: HashSet<WorkerId>,
    total_workers: usize,
    final_stats: Vec<(WorkerId, WorkerStats)>,
    done_waiters: Vec<Sender<ExecSummary>>,
    done_at: Option<Instant>,

    // Per-op completion / port completion (Maestro).
    ops_completed: HashMap<usize, usize>,
    ops_waiters: Vec<(Vec<usize>, Sender<()>)>,
    port_completed: HashMap<(usize, usize), usize>,
    port_waiters: Vec<(usize, usize, Sender<()>)>,

    // Breakpoints.
    /// Last local-breakpoint predicate installed per operator, so
    /// workers spawned mid-run by elastic scaling inherit it.
    local_bps: HashMap<usize, Option<LocalPredicate>>,
    next_bp_id: u64,
    breakpoints: HashMap<u64, BpState>,
    bp_waiters: Vec<Sender<BpHit>>,
    bp_hits: Vec<BpHit>,

    // First-output per op.
    first_output: HashMap<usize, f64>,

    // Fault tolerance.
    replay_log: ReplayLog,
    snapshot_outstanding: HashSet<WorkerId>,
    snapshot_acc: Checkpoint,
    checkpoint_reply: Option<Sender<Checkpoint>>,

    // Supervision (panic containment + heartbeat detection + automatic
    // replay-based recovery).
    /// Failures declared but not yet recovered: (worker, cause,
    /// declaration instant). Populated by `WorkerFailed` containment
    /// events and by the heartbeat sweep; consumed by
    /// `check_supervision` back on the run loop (fence wait loops only
    /// *observe* it to abort early).
    pending_failures: Vec<(WorkerId, String, Instant)>,
    /// Heartbeat sweep state: worker → (last counter value, instant it
    /// last changed).
    last_beats: HashMap<WorkerId, (u64, Instant)>,
    /// Latest completed checkpoint retained as the recovery restore
    /// point (`None` → recovery restores from scratch with the full
    /// replay log). Invalidated by scale/migration fences: a checkpoint
    /// keyed to the old worker set cannot restore onto the new one.
    latest_checkpoint: Option<Checkpoint>,
    /// When the next automatic checkpoint is due (`None` = disabled).
    next_checkpoint: Option<Instant>,
    /// The in-flight pause/snapshot cycle was started by the automatic
    /// checkpointer (no driver reply to send).
    auto_checkpoint: bool,
    /// Completion instant of the previous automatic checkpoint plus the
    /// accumulated gap stats (observed-cadence metric).
    last_auto_checkpoint_at: Option<Instant>,
    auto_cp_gap_sum_ms: f64,
    auto_cp_gaps: u64,
    /// Recovery cycles performed so far; compared against
    /// `Config::recovery_max_retries` before each new cycle.
    recovery_attempts: u32,
    /// Token sequence for the post-teardown stale-event drain.
    recovery_epoch: u64,
    supervision: SupervisionStats,
    exec_error: Option<ExecError>,

    // Out-of-core (engine::spill): the execution-shared budget,
    // counters and spill directory, cloned into every spawned worker's
    // context (including scale spawns and recovery respawns).
    spill: crate::engine::spill::SpillCtx,

    // Plugin (Reshape).
    plugin: Option<Box<dyn CoordPlugin>>,
    next_tick: Instant,

    shutdown: bool,
}

struct BpState {
    op: usize,
    machine: GlobalBreakpoint,
    /// τ deadline if the timer is armed.
    deadline: Option<Instant>,
    registered: Instant,
}

impl Execution {
    /// Deploy and start a workflow (sources auto-start).
    pub fn start(workflow: Workflow, config: Config) -> Execution {
        Self::start_inner(workflow, config, None, true, None)
    }

    /// Deploy with a coordinator plugin (Reshape).
    pub fn start_with_plugin(
        workflow: Workflow,
        config: Config,
        plugin: Box<dyn CoordPlugin>,
    ) -> Execution {
        Self::start_inner(workflow, config, Some(plugin), true, None)
    }

    /// Deploy with dormant sources (Maestro region scheduling: sources
    /// wait for `start_sources`).
    pub fn start_scheduled(workflow: Workflow, config: Config) -> Execution {
        Self::start_inner(workflow, config, None, false, None)
    }

    /// Dormant sources + a coordinator plugin (Maestro × Reshape: the
    /// full Texera stack).
    pub fn start_scheduled_with_plugin(
        workflow: Workflow,
        config: Config,
        plugin: Box<dyn CoordPlugin>,
    ) -> Execution {
        Self::start_inner(workflow, config, Some(plugin), false, None)
    }

    /// Recover from a checkpoint: restores every worker's snapshot and
    /// replays the control log (§2.6.2).
    pub fn recover(
        workflow: Workflow,
        config: Config,
        checkpoint: Checkpoint,
        log: Vec<LogRecord>,
    ) -> Execution {
        Self::start_inner(workflow, config, None, true, Some((checkpoint, log)))
    }

    fn start_inner(
        workflow: Workflow,
        config: Config,
        plugin: Option<Box<dyn CoordPlugin>>,
        sources_autostart: bool,
        recovery: Option<(Checkpoint, Vec<LogRecord>)>,
    ) -> Execution {
        workflow.validate().expect("invalid workflow");
        let (cmd_tx, rx) = channel::<CoordMsg>();
        let (ev_tx, ev_rx) = channel::<WorkerEvent>();
        // Forward worker events into the coordinator's merged channel.
        {
            let cmd_tx = cmd_tx.clone();
            std::thread::spawn(move || {
                while let Ok(ev) = ev_rx.recv() {
                    if cmd_tx.send(CoordMsg::Event(ev)).is_err() {
                        break;
                    }
                }
            });
        }

        let (mut checkpoint, log) = recovery
            .map(|(c, l)| (Some(c), l))
            .unwrap_or((None, Vec::new()));

        // One out-of-core context per execution: shared budget, spill
        // counters and (lazily created) spill directory. NOTE: a
        // checkpoint's spill manifests reference files in the spill
        // directory of the execution that *wrote* them — valid for
        // in-process recovery (the coordinator's redeploy shares this
        // context) but not across a driver-level `recover` once the
        // original execution has been dropped.
        let spill = crate::engine::spill::SpillCtx::new(&config);

        // --- Deploy the actor DAG (§2.3.2). ---
        // 1. Mailboxes for every worker.
        let mut senders: HashMap<WorkerId, DataSender> = HashMap::new();
        let mut mailboxes: HashMap<WorkerId, crate::engine::channel::Mailbox> = HashMap::new();
        for (op_idx, op) in workflow.ops.iter().enumerate() {
            for w in 0..op.workers {
                let id = WorkerId::new(op_idx, w);
                let (tx, mb) = mailbox(config.data_queue_cap);
                senders.insert(id, tx);
                mailboxes.insert(id, mb);
            }
        }
        // 2. Per-port upstream sender counts.
        let mut upstream: HashMap<usize, Vec<usize>> = HashMap::new();
        for (op_idx, op) in workflow.ops.iter().enumerate() {
            let mut counts = vec![0usize; op.input_partitioning.len()];
            for e in workflow.in_edges(op_idx) {
                counts[e.to_port] += workflow.ops[e.from].workers;
            }
            upstream.insert(op_idx, counts);
        }
        // 3. Spawn workers.
        let mut handles = HashMap::new();
        for (op_idx, op) in workflow.ops.iter().enumerate() {
            let peer_senders: Vec<DataSender> = (0..op.workers)
                .map(|w| senders[&WorkerId::new(op_idx, w)].clone())
                .collect();
            let port_key_fields: Vec<Option<usize>> = op
                .input_partitioning
                .iter()
                .map(|s| match s {
                    crate::engine::partitioner::PartitionScheme::Hash { key } => Some(*key),
                    crate::engine::partitioner::PartitionScheme::Range { key, .. } => {
                        Some(*key)
                    }
                    _ => None,
                })
                .collect();
            for w in 0..op.workers {
                let id = WorkerId::new(op_idx, w);
                let mb = mailboxes.remove(&id).unwrap();
                let control = mb.control.clone();
                let gauges = mb.gauges.clone();
                // Output edges.
                let mut outputs = Vec::new();
                for e in workflow.out_edges(op_idx) {
                    let dst = &workflow.ops[e.to];
                    let scheme = dst.input_partitioning[e.to_port].clone();
                    let dst_senders: Vec<DataSender> = (0..dst.workers)
                        .map(|d| senders[&WorkerId::new(e.to, d)].clone())
                        .collect();
                    outputs.push(
                        OutputEdge::new(
                            e.to,
                            e.to_port,
                            Partitioner::new(scheme, dst.workers, w),
                            dst_senders,
                        )
                        .with_columnar(config.columnar),
                    );
                }
                let snapshot = checkpoint
                    .as_mut()
                    .and_then(|c| c.workers.remove(&id));
                let ctx = WorkerContext {
                    id,
                    mailbox: mb,
                    event_tx: ev_tx.clone(),
                    outputs,
                    upstream_counts: upstream[&op_idx].clone(),
                    peers: peer_senders.clone(),
                    port_key_fields: port_key_fields.clone(),
                    source: if op.is_source {
                        Some((op.source_builder.as_ref().expect("source op without source"))(
                            w, op.workers,
                        ))
                    } else {
                        None
                    },
                    source_autostart: sources_autostart,
                    batch_size: config.batch_size,
                    ctrl_check_interval: config.ctrl_check_interval,
                    ft_log: config.ft_log,
                    snapshot,
                    scatter_merge: op.scatter_merge,
                    scale_epoch: 0,
                    initial_eofs: None,
                    start_paused: false,
                    columnar: config.columnar,
                    fault_plan: config.fault_plan.clone(),
                    spill: spill.clone(),
                };
                let builder = op.builder.clone();
                let workers = op.workers;
                let thread = std::thread::Builder::new()
                    .name(format!("{}", id))
                    .spawn(move || run_worker(ctx, builder(w, workers)))
                    .expect("spawn worker");
                handles.insert(
                    id,
                    WorkerHandle { control, gauges, thread: Some(thread) },
                );
            }
        }
        // The coordinator keeps `senders` and `ev_tx`: elastic scaling
        // spawns workers and re-injects surrendered input mid-run.
        // (Workers therefore never observe a data-channel disconnect
        // before `Die`, which the teardown path already sends.)

        // Replay the control log (recovery).
        if !log.is_empty() {
            let mut per_worker: HashMap<WorkerId, Vec<LogRecord>> = HashMap::new();
            for r in log {
                per_worker.entry(r.worker).or_default().push(r);
            }
            for (id, recs) in per_worker {
                if let Some(h) = handles.get(&id) {
                    h.control
                        .send(ControlMessage::ReplayLog(recs), Duration::ZERO);
                }
            }
        }

        let total_workers = workflow.total_workers();
        let started = Instant::now();
        let period = plugin
            .as_ref()
            .map(|p| p.period())
            .unwrap_or(Duration::from_secs(3600));
        let first_auto_checkpoint = if config.checkpoint_interval_ms > 0 {
            Some(started + Duration::from_millis(config.checkpoint_interval_ms))
        } else {
            None
        };
        let coord = Coordinator {
            workflow,
            config,
            handles,
            rx,
            started,
            senders,
            ev_tx,
            scale_collect: HashMap::new(),
            deferred: Vec::new(),
            scale_requests: RefCell::new(Vec::new()),
            fence_epoch: 0,
            scale_owner: HashMap::new(),
            sources_autostart,
            started_sources: HashSet::new(),
            live_mats: Vec::new(),
            dormant_ops: HashSet::new(),
            pending_mat_activations: HashMap::new(),
            pause_outstanding: HashSet::new(),
            pause_reply: None,
            user_paused: false,
            completed: HashSet::new(),
            total_workers,
            final_stats: Vec::new(),
            done_waiters: Vec::new(),
            done_at: None,
            ops_completed: HashMap::new(),
            ops_waiters: Vec::new(),
            port_completed: HashMap::new(),
            port_waiters: Vec::new(),
            local_bps: HashMap::new(),
            next_bp_id: 1,
            breakpoints: HashMap::new(),
            bp_waiters: Vec::new(),
            bp_hits: Vec::new(),
            first_output: HashMap::new(),
            replay_log: ReplayLog::default(),
            snapshot_outstanding: HashSet::new(),
            snapshot_acc: Checkpoint::default(),
            checkpoint_reply: None,
            pending_failures: Vec::new(),
            last_beats: HashMap::new(),
            latest_checkpoint: None,
            next_checkpoint: first_auto_checkpoint,
            auto_checkpoint: false,
            last_auto_checkpoint_at: None,
            auto_cp_gap_sum_ms: 0.0,
            auto_cp_gaps: 0,
            recovery_attempts: 0,
            recovery_epoch: 0,
            supervision: SupervisionStats::default(),
            exec_error: None,
            spill: spill.clone(),
            plugin,
            next_tick: started + period,
            shutdown: false,
        };
        let coord_handle = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coord.run())
            .expect("spawn coordinator");
        Execution { cmd_tx, coord: Some(coord_handle), started, spill }
    }

    fn cmd(&self, c: Command) {
        let _ = self.cmd_tx.send(CoordMsg::Cmd(c));
    }

    /// Pause the workflow; returns the pause latency (time until every
    /// live worker acked).
    pub fn pause(&self) -> Duration {
        let (tx, rx) = channel();
        self.cmd(Command::Pause { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Resume all workers.
    pub fn resume(&self) {
        let (tx, rx) = channel();
        self.cmd(Command::Resume { reply: tx });
        rx.recv().expect("coordinator gone");
    }

    /// Current stats of every worker.
    pub fn stats(&self) -> Vec<(WorkerId, WorkerStats)> {
        let (tx, rx) = channel();
        self.cmd(Command::Stats { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Install a local conditional breakpoint on an operator's output.
    pub fn set_local_breakpoint(&self, op: usize, pred: Option<LocalPredicate>) {
        let (tx, rx) = channel();
        self.cmd(Command::SetLocalBp { op, pred, reply: tx });
        rx.recv().expect("coordinator gone");
    }

    /// Install a global COUNT breakpoint; returns its id.
    pub fn set_count_breakpoint(&self, op: usize, total: u64) -> u64 {
        let (tx, rx) = channel();
        self.cmd(Command::SetCountBp { op, total, reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Install a global SUM breakpoint; returns its id.
    pub fn set_sum_breakpoint(&self, op: usize, total: f64, field: usize, tail: f64) -> u64 {
        let (tx, rx) = channel();
        self.cmd(Command::SetSumBp { op, total, field, tail, reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Block until a breakpoint hits (workflow is paused on return).
    pub fn await_breakpoint(&self) -> BpHit {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitBpHit { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Patch an operator's runtime parameters on all its workers.
    pub fn modify_operator(&self, op: usize, param: &str, value: &str) {
        let (tx, rx) = channel();
        self.cmd(Command::Modify {
            op,
            patch: OpPatch { param: param.into(), value: value.into() },
            reply: tx,
        });
        rx.recv().expect("coordinator gone");
    }

    /// Quiesced checkpoint: pause-all → snapshot → resume.
    pub fn checkpoint(&self) -> Checkpoint {
        let (tx, rx) = channel();
        self.cmd(Command::TakeCheckpoint { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Extract the control-replay log accumulated since the last
    /// checkpoint.
    pub fn take_replay_log(&self) -> Vec<LogRecord> {
        let (tx, rx) = channel();
        self.cmd(Command::TakeReplayLog { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Simulate a crash of specific workers (they die without acking).
    pub fn crash_workers(&self, workers: Vec<WorkerId>) {
        self.cmd(Command::CrashWorkers { workers });
    }

    /// Maestro: start dormant sources of the given operators.
    pub fn start_sources(&self, ops: Vec<usize>) {
        let (tx, rx) = channel();
        self.cmd(Command::StartSources { ops, reply: tx });
        rx.recv().expect("coordinator gone");
    }

    /// Maestro: block until the given operators complete.
    pub fn await_ops(&self, ops: Vec<usize>) {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitOps { ops, reply: tx });
        rx.recv().expect("coordinator gone");
    }

    /// Maestro: block until `op`'s input `port` saw EOF on all workers.
    pub fn await_port(&self, op: usize, port: usize) {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitPort { op, port, reply: tx });
        rx.recv().expect("coordinator gone");
    }

    /// Enable/disable per-key workload tracking on an operator.
    pub fn track_keys(&self, op: usize, on: bool) {
        self.cmd(Command::TrackKeys { op, on });
    }

    /// Elastic scaling: change `op`'s worker count to `new_workers`
    /// without stopping the workflow (engine::scale). Works for every
    /// operator class — including sources (splittable scan ranges),
    /// scatter-merge operators (epoch-keyed peer barrier) and
    /// broadcast-input operators (build-side replication). Blocks until
    /// the fenced epoch completes and returns its duration; returns
    /// `Duration::ZERO` when the request was refused: unknown operator,
    /// zero/unchanged count, the operator already has completed workers
    /// (the EOF cascade is under way), or the operator is owned by the
    /// other scaling party (the `AutoscalePlugin` vs driver/Maestro
    /// ownership guard — whoever scales an operator first owns it).
    pub fn scale_operator(&self, op: usize, new_workers: usize) -> Duration {
        let (tx, rx) = channel();
        self.cmd(Command::Scale { op, new_workers, reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Live plan migration (engine::migrate): apply a structural plan
    /// delta — repartition a live edge, insert/remove a
    /// materialization, re-plan worker counts — as an ordered sequence
    /// of fenced steps. Blocks until the sequence completes (or
    /// aborts-and-restores) and returns the per-step outcome trail.
    pub fn migrate(&self, delta: PlanDelta) -> MigrationOutcome {
        let (tx, rx) = channel();
        self.cmd(Command::Migrate { delta, reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Maestro: block until the given operators complete or `timeout`
    /// passes; returns whether they completed. The mid-region
    /// re-planner polls this to interleave probe-stream observation
    /// with region progress. (A timed-out waiter's reply channel is
    /// simply dropped; the coordinator's later send to it is ignored.)
    pub fn await_ops_timeout(&self, ops: Vec<usize>, timeout: Duration) -> bool {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitOps { ops, reply: tx });
        rx.recv_timeout(timeout).is_ok()
    }

    /// Send a raw control message (tests, baselines).
    pub fn send_control(&self, to: WorkerId, msg: ControlMessage) {
        self.cmd(Command::SendControl { to, msg });
    }

    /// Block until the whole workflow completes; returns the summary.
    pub fn join(&self) -> ExecSummary {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitDone { reply: tx });
        rx.recv().expect("coordinator gone")
    }

    /// Register a completion waiter **without blocking**: the returned
    /// channel receives the run's [`ExecSummary`] exactly once —
    /// immediately if the run has already finished. If the execution is
    /// torn down before completing (the `Execution` is dropped), the
    /// channel disconnects instead. The serving layer
    /// (`crate::service`) uses this to turn each job's completion into
    /// a queue message rather than parking its loop inside `join`.
    pub fn on_done(&self) -> Receiver<ExecSummary> {
        let (tx, rx) = channel();
        self.cmd(Command::AwaitDone { reply: tx });
        rx
    }

    /// Elapsed time since deployment.
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Live out-of-core counters (bytes spilled/read back, partitions,
    /// budget high-water). Readable at any time without a coordinator
    /// round-trip — Maestro's scheduler calibrates its spill-bandwidth
    /// cost constant from these between region activations.
    pub fn spill_stats(&self) -> crate::metrics::SpillStats {
        self.spill.counters.snapshot(&self.spill.budget)
    }

    /// The execution's spill directory, if anything was spilled (the
    /// cleanup regression tests assert it disappears at teardown).
    pub fn spill_dir(&self) -> Option<std::path::PathBuf> {
        self.spill.dir_path()
    }
}

impl Drop for Execution {
    fn drop(&mut self) {
        self.cmd(Command::Shutdown);
        if let Some(h) = self.coord.take() {
            let _ = h.join();
        }
    }
}

impl Coordinator {
    fn send_control(&self, to: WorkerId, msg: ControlMessage) {
        if let Some(h) = self.handles.get(&to) {
            h.control
                .send(msg, Duration::from_millis(self.config.ctrl_delay_ms));
        }
    }

    fn broadcast_op(&self, op: usize, msg: ControlMessage) {
        for w in 0..self.workflow.ops[op].workers {
            self.send_control(WorkerId::new(op, w), msg.clone());
        }
    }

    fn broadcast_all(&self, msg: ControlMessage) {
        for id in self.handles.keys() {
            self.send_control(*id, msg.clone());
        }
    }

    fn plugin_ctx(&self) -> (
        HashMap<WorkerId, Arc<WorkerGauges>>,
        HashMap<WorkerId, Arc<ControlInbox>>,
    ) {
        let gauges = self
            .handles
            .iter()
            .map(|(id, h)| (*id, h.gauges.clone()))
            .collect();
        let controls = self
            .handles
            .iter()
            .map(|(id, h)| (*id, h.control.clone()))
            .collect();
        (gauges, controls)
    }

    fn run_plugin_tick(&mut self) {
        let Some(mut plugin) = self.plugin.take() else { return };
        let (gauges, controls) = self.plugin_ctx();
        {
            let ctx = PluginCtx {
                workflow: &self.workflow,
                gauges: &gauges,
                controls: &controls,
                config: &self.config,
                started: self.started,
                completed: &self.completed,
                scale_requests: &self.scale_requests,
            };
            plugin.tick(&ctx);
        }
        self.plugin = Some(plugin);
    }

    fn run_plugin_event(&mut self, ev: &WorkerEvent) {
        let Some(mut plugin) = self.plugin.take() else { return };
        let (gauges, controls) = self.plugin_ctx();
        {
            let ctx = PluginCtx {
                workflow: &self.workflow,
                gauges: &gauges,
                controls: &controls,
                config: &self.config,
                started: self.started,
                completed: &self.completed,
                scale_requests: &self.scale_requests,
            };
            plugin.on_event(ev, &ctx);
        }
        self.plugin = Some(plugin);
    }

    fn begin_pause(&mut self, reply: Option<Sender<Duration>>) {
        self.pause_outstanding = self
            .handles
            .keys()
            .copied()
            .collect::<HashSet<_>>();
        if let Some(r) = reply {
            self.pause_reply = Some((r, Instant::now()));
        }
        self.broadcast_all(ControlMessage::Pause);
        // Completed workers still ack Pause (they are parked, control-
        // responsive); nothing special needed.
        if self.pause_outstanding.is_empty() {
            self.finish_pause();
        }
    }

    fn finish_pause(&mut self) {
        if let Some((reply, t0)) = self.pause_reply.take() {
            let _ = reply.send(t0.elapsed());
        }
        // If a checkpoint (manual or automatic) is waiting for
        // quiescence, request snapshots.
        if (self.checkpoint_reply.is_some() || self.auto_checkpoint)
            && self.snapshot_outstanding.is_empty()
        {
            self.snapshot_outstanding = self.handles.keys().copied().collect();
            self.snapshot_acc = Checkpoint::default();
            self.broadcast_all(ControlMessage::TakeSnapshot);
        }
    }

    fn summary(&self) -> ExecSummary {
        let mut produced_by_op: HashMap<usize, u64> = HashMap::new();
        for (id, st) in &self.final_stats {
            *produced_by_op.entry(id.op).or_insert(0) += st.produced;
        }
        ExecSummary {
            elapsed: self
                .done_at
                .map(|t| t - self.started)
                .unwrap_or_else(|| self.started.elapsed()),
            worker_stats: self.final_stats.clone(),
            first_output: self.first_output.clone(),
            produced_by_op,
            supervision: self.supervision.clone(),
            spill: self.spill.counters.snapshot(&self.spill.budget),
            error: self.exec_error.clone(),
        }
    }

    fn maybe_done(&mut self) {
        if self.completed.len() == self.total_workers && self.done_at.is_none() {
            self.done_at = Some(Instant::now());
            let summary = self.summary();
            for w in self.done_waiters.drain(..) {
                let _ = w.send(summary.clone());
            }
        }
    }

    fn on_bp_action(&mut self, id: u64, action: BpAction) {
        let (op, sum_field, registered) = match self.breakpoints.get(&id) {
            Some(st) => (st.op, st.machine.sum_field, st.registered),
            None => return,
        };
        match action {
            BpAction::None => {}
            BpAction::StartTimer => {
                let dl =
                    Instant::now() + Duration::from_millis(self.config.breakpoint_tau_ms);
                if let Some(st) = self.breakpoints.get_mut(&id) {
                    st.deadline = Some(dl);
                }
            }
            BpAction::Inquire(workers) => {
                if let Some(st) = self.breakpoints.get_mut(&id) {
                    st.deadline = None;
                }
                for w in workers {
                    self.send_control(WorkerId::new(op, w), ControlMessage::Inquire { id });
                }
            }
            BpAction::Assign(assignments) => {
                if let Some(st) = self.breakpoints.get_mut(&id) {
                    st.deadline = None;
                }
                for (w, amount) in assignments {
                    self.send_control(
                        WorkerId::new(op, w),
                        ControlMessage::AssignTarget(BreakpointTarget {
                            id,
                            amount,
                            sum_field,
                        }),
                    );
                }
            }
            BpAction::Hit => {
                let elapsed = registered.elapsed();
                let overshoot = self
                    .breakpoints
                    .get(&id)
                    .map(|st| (-st.machine.remaining()).max(0.0))
                    .unwrap_or(0.0);
                let hit = BpHit { id, tuple: None, elapsed, overshoot };
                self.breakpoints.remove(&id);
                self.record_hit(hit);
            }
        }
    }

    fn record_hit(&mut self, hit: BpHit) {
        // Pause the whole workflow (the principal "sends a message to
        // the controller to pause the entire workflow").
        self.begin_pause(None);
        for w in self.bp_waiters.drain(..) {
            let _ = w.send(hit.clone());
        }
        self.bp_hits.push(hit);
    }

    fn handle_event(&mut self, ev: WorkerEvent) {
        self.run_plugin_event(&ev);
        match ev {
            WorkerEvent::PausedAck { worker, .. } => {
                self.pause_outstanding.remove(&worker);
                if self.pause_outstanding.is_empty() {
                    self.finish_pause();
                }
            }
            WorkerEvent::ResumedAck { .. } => {}
            WorkerEvent::Stats { .. } => {
                // Collected synchronously through gauges instead.
            }
            WorkerEvent::LocalBreakpointHit { tuple, .. } => {
                let hit = BpHit {
                    id: 0,
                    tuple: Some(tuple),
                    elapsed: Duration::ZERO,
                    overshoot: 0.0,
                };
                self.record_hit(hit);
            }
            WorkerEvent::TargetReached { worker, id, produced } => {
                if let Some(st) = self.breakpoints.get_mut(&id) {
                    let act = st.machine.on_target_reached(worker.idx, produced);
                    self.on_bp_action(id, act);
                }
            }
            WorkerEvent::InquiryReport { worker, id, produced } => {
                if let Some(st) = self.breakpoints.get_mut(&id) {
                    let act = st.machine.on_inquiry_report(worker.idx, produced);
                    self.on_bp_action(id, act);
                }
            }
            WorkerEvent::Snapshot { worker, snap } => {
                if self.snapshot_outstanding.remove(&worker) {
                    self.snapshot_acc.workers.insert(worker, snap);
                    if self.snapshot_outstanding.is_empty() {
                        // Checkpoint complete: clear the replay log (its
                        // effects are in state) and resume.
                        self.replay_log.clear();
                        let cp = std::mem::take(&mut self.snapshot_acc);
                        self.supervision.last_checkpoint_tuples =
                            cp.total_state_tuples() as u64;
                        if self.auto_checkpoint {
                            // Timer-driven checkpoint: retain as the
                            // recovery restore point, fold the cadence
                            // stats, schedule the next one.
                            self.auto_checkpoint = false;
                            self.supervision.auto_checkpoints += 1;
                            let now = Instant::now();
                            if let Some(prev) = self.last_auto_checkpoint_at {
                                self.auto_cp_gap_sum_ms +=
                                    now.duration_since(prev).as_secs_f64() * 1e3;
                                self.auto_cp_gaps += 1;
                                self.supervision.checkpoint_interval_ms_observed =
                                    self.auto_cp_gap_sum_ms / self.auto_cp_gaps as f64;
                            }
                            self.last_auto_checkpoint_at = Some(now);
                            if self.config.checkpoint_interval_ms > 0 {
                                self.next_checkpoint = Some(
                                    now + Duration::from_millis(
                                        self.config.checkpoint_interval_ms,
                                    ),
                                );
                            }
                            self.latest_checkpoint = Some(cp);
                            if !self.user_paused {
                                self.broadcast_all(ControlMessage::Resume);
                            }
                        } else {
                            if let Some(r) = self.checkpoint_reply.take() {
                                // Also retain a copy as the recovery
                                // restore point when supervision can use
                                // it (replay-log recovery enabled).
                                if self.config.ft_log {
                                    self.latest_checkpoint = Some(cp.duplicate());
                                }
                                let _ = r.send(cp);
                            }
                            self.broadcast_all(ControlMessage::Resume);
                        }
                    }
                }
            }
            WorkerEvent::StateApplied { .. } => {}
            WorkerEvent::PortCompleted { worker, port } => {
                let c = self.port_completed.entry((worker.op, port)).or_insert(0);
                *c += 1;
                let full = *c >= self.workflow.ops[worker.op].workers;
                if full {
                    let mut i = 0;
                    while i < self.port_waiters.len() {
                        if self.port_waiters[i].0 == worker.op
                            && self.port_waiters[i].1 == port
                        {
                            let (_, _, r) = self.port_waiters.swap_remove(i);
                            let _ = r.send(());
                        } else {
                            i += 1;
                        }
                    }
                }
            }
            WorkerEvent::MarkerAligned { .. } => {}
            WorkerEvent::Completed { worker, stats } => {
                if self.completed.insert(worker) {
                    self.final_stats.push((worker, stats));
                    let done = {
                        let c = self.ops_completed.entry(worker.op).or_insert(0);
                        *c += 1;
                        *c
                    };
                    // Live-mat activation: once every writer worker has
                    // completed the store is final, so the paired
                    // dormant reader can start streaming it.
                    if done >= self.workflow.ops[worker.op].workers {
                        if let Some(reader) =
                            self.pending_mat_activations.remove(&worker.op)
                        {
                            self.dormant_ops.remove(&reader);
                            self.started_sources.insert(reader);
                            self.broadcast_op(reader, ControlMessage::StartSource);
                        }
                    }
                    // Also counts as a pause ack if one is outstanding.
                    self.pause_outstanding.remove(&worker);
                    if self.pause_reply.is_some() && self.pause_outstanding.is_empty() {
                        self.finish_pause();
                    }
                    self.notify_ops_waiters();
                    self.maybe_done();
                }
            }
            WorkerEvent::ScaleState { worker, state, pending, source } => {
                self.scale_collect
                    .insert(worker, ScaleSurrender { state, pending, source });
            }
            WorkerEvent::Log(rec) => {
                self.replay_log.append(rec);
            }
            WorkerEvent::FirstOutput { worker, at } => {
                self.first_output
                    .entry(worker.op)
                    .or_insert_with(|| at.duration_since(self.started).as_secs_f64());
            }
            WorkerEvent::WorkerFailed { worker, cause, at } => {
                // Panic containment declared a crash. Record it; the
                // run loop (or an aborting fence) acts on it — recovery
                // never runs from inside a fence's event pump.
                self.supervision.crashes_detected += 1;
                self.supervision
                    .observe_detection_ms(at.elapsed().as_secs_f64() * 1e3);
                self.pending_failures.push((worker, cause, Instant::now()));
            }
            WorkerEvent::EpochMark { .. } => {
                // Recovery drain marker: consumed inside `redeploy`;
                // one reaching the normal loop is already spent.
            }
        }
    }

    fn notify_ops_waiters(&mut self) {
        let mut i = 0;
        while i < self.ops_waiters.len() {
            let all_done = self.ops_waiters[i].0.iter().all(|op| {
                self.ops_completed.get(op).copied().unwrap_or(0)
                    >= self.workflow.ops[*op].workers
            });
            if all_done {
                let (_, r) = self.ops_waiters.swap_remove(i);
                let _ = r.send(());
            } else {
                i += 1;
            }
        }
    }

    fn handle_cmd(&mut self, cmd: Command) {
        match cmd {
            Command::Pause { reply } => {
                self.user_paused = true;
                self.begin_pause(Some(reply));
            }
            Command::Resume { reply } => {
                self.user_paused = false;
                self.broadcast_all(ControlMessage::Resume);
                let _ = reply.send(());
            }
            Command::Stats { reply } => {
                // Completed workers report their exact final stats (the
                // gauges can lag output emitted inside `finish_port`/
                // `finish`, e.g. a group-by's entire result — Maestro's
                // re-planner reads these as observed cardinalities, so
                // exactness matters); live workers read gauges directly
                // (cheap, no round trip).
                let done: HashMap<WorkerId, &WorkerStats> =
                    self.final_stats.iter().map(|(id, s)| (*id, s)).collect();
                let mut out = Vec::new();
                for (id, h) in &self.handles {
                    let stats = match done.get(id) {
                        Some(s) => (*s).clone(),
                        None => WorkerStats {
                            processed: h.gauges.processed.load(Ordering::Relaxed) as u64,
                            produced: h.gauges.produced.load(Ordering::Relaxed) as u64,
                            queued: h.gauges.queued.load(Ordering::Relaxed),
                            state_tuples: 0,
                            busy_ns: h.gauges.busy_ns.load(Ordering::Relaxed).max(0) as u64,
                        },
                    };
                    out.push((*id, stats));
                }
                out.sort_by_key(|(id, _)| *id);
                let _ = reply.send(out);
            }
            Command::SetLocalBp { op, pred, reply } => {
                self.local_bps.insert(op, pred.clone());
                self.broadcast_op(op, ControlMessage::SetLocalBreakpoint(pred));
                let _ = reply.send(());
            }
            Command::SetCountBp { op, total, reply } => {
                let id = self.next_bp_id;
                self.next_bp_id += 1;
                let workers = self.workflow.ops[op].workers;
                let mut machine = GlobalBreakpoint::count(id, total, workers);
                let init = machine.initial_assignments();
                self.breakpoints.insert(
                    id,
                    BpState { op, machine, deadline: None, registered: Instant::now() },
                );
                for (w, amount) in init {
                    self.send_control(
                        WorkerId::new(op, w),
                        ControlMessage::AssignTarget(BreakpointTarget {
                            id,
                            amount,
                            sum_field: None,
                        }),
                    );
                }
                let _ = reply.send(id);
            }
            Command::SetSumBp { op, total, field, tail, reply } => {
                let id = self.next_bp_id;
                self.next_bp_id += 1;
                let workers = self.workflow.ops[op].workers;
                let mut machine = GlobalBreakpoint::sum(id, total, field, workers, tail);
                let init = machine.initial_assignments();
                self.breakpoints.insert(
                    id,
                    BpState { op, machine, deadline: None, registered: Instant::now() },
                );
                for (w, amount) in init {
                    self.send_control(
                        WorkerId::new(op, w),
                        ControlMessage::AssignTarget(BreakpointTarget {
                            id,
                            amount,
                            sum_field: Some(field),
                        }),
                    );
                }
                let _ = reply.send(id);
            }
            Command::AwaitBpHit { reply } => {
                if let Some(hit) = self.bp_hits.pop() {
                    let _ = reply.send(hit);
                } else {
                    self.bp_waiters.push(reply);
                }
            }
            Command::Modify { op, patch, reply } => {
                self.broadcast_op(op, ControlMessage::ModifyOperator(patch));
                let _ = reply.send(());
            }
            Command::TakeCheckpoint { reply } => {
                self.checkpoint_reply = Some(reply);
                self.begin_pause(None);
            }
            Command::TakeReplayLog { reply } => {
                let mut all = Vec::new();
                for id in self.handles.keys() {
                    all.extend(self.replay_log.for_worker(*id));
                }
                let _ = reply.send(all);
            }
            Command::CrashWorkers { workers } => {
                for w in workers {
                    self.send_control(w, ControlMessage::Die);
                    // Dead workers will never ack or complete; remove
                    // them from accounting so teardown doesn't hang.
                    if let Some(mut h) = self.handles.remove(&w) {
                        if let Some(t) = h.thread.take() {
                            let _ = t.join();
                        }
                    }
                    self.total_workers -= 1;
                    self.completed.remove(&w);
                }
            }
            Command::StartSources { ops, reply } => {
                for op in ops {
                    self.started_sources.insert(op);
                    self.broadcast_op(op, ControlMessage::StartSource);
                }
                let _ = reply.send(());
            }
            Command::AwaitOps { ops, reply } => {
                self.ops_waiters.push((ops, reply));
                self.notify_ops_waiters();
            }
            Command::AwaitPort { op, port, reply } => {
                let done = self.port_completed.get(&(op, port)).copied().unwrap_or(0)
                    >= self.workflow.ops[op].workers;
                if done {
                    let _ = reply.send(());
                } else {
                    self.port_waiters.push((op, port, reply));
                }
            }
            Command::AwaitDone { reply } => {
                if self.done_at.is_some() {
                    let _ = reply.send(self.summary());
                } else {
                    self.done_waiters.push(reply);
                }
            }
            Command::SendControl { to, msg } => self.send_control(to, msg),
            Command::Scale { op, new_workers, reply } => {
                // Ownership/veto guard: once the autoscale plugin has
                // scaled an operator, driver-side requests (Maestro's
                // re-planner, the API) for that operator are refused —
                // and vice versa — so the two policies can never
                // interleave conflicting parallelism changes
                // (last-writer-wins) on one operator.
                let vetoed =
                    matches!(self.scale_owner.get(&op), Some(ScaleOwner::Plugin));
                let d = if vetoed {
                    Duration::ZERO
                } else {
                    self.do_scale(op, new_workers)
                };
                if d > Duration::ZERO {
                    self.scale_owner.insert(op, ScaleOwner::Driver);
                }
                let _ = reply.send(d);
                self.drain_deferred();
            }
            Command::Migrate { delta, reply } => {
                let outcome = self.do_migrate(delta);
                let _ = reply.send(outcome);
                self.drain_deferred();
            }
            Command::TrackKeys { op, on } => {
                for w in 0..self.workflow.ops[op].workers {
                    if let Some(h) = self.handles.get(&WorkerId::new(op, w)) {
                        h.gauges.track_keys.store(on, Ordering::Relaxed);
                    }
                }
            }
            Command::Shutdown => {
                self.shutdown = true;
            }
        }
    }

    // ---- elastic scaling (engine::scale) -------------------------------

    /// Pump one message while a fence is open: worker events are handled
    /// normally (pause acks, completions, scale-state replies); driver
    /// commands are deferred until the fence closes so the epoch stays
    /// atomic with respect to the control API.
    fn pump_fence(&mut self) {
        match self.rx.recv_timeout(Duration::from_millis(5)) {
            Ok(CoordMsg::Cmd(c)) => self.deferred.push(c),
            Ok(CoordMsg::Event(e)) => self.handle_event(e),
            Err(_) => {}
        }
    }

    /// Replay commands that arrived while a fence was open.
    fn drain_deferred(&mut self) {
        while !self.deferred.is_empty() {
            let cmds: Vec<Command> = self.deferred.drain(..).collect();
            for c in cmds {
                self.handle_cmd(c);
            }
        }
    }

    /// Live workers of `op` (they will each send one `End` downstream,
    /// either already — completed — or eventually).
    fn live_workers_of(&self, op: usize) -> usize {
        self.handles.keys().filter(|w| w.op == op).count()
    }

    /// Expected `End` count per input port of `op`, from the *live*
    /// upstream worker sets (completed workers already sent theirs,
    /// alive ones will; retired workers never do).
    fn expected_ends(&self, op: usize) -> Vec<usize> {
        let mut counts = vec![0usize; self.workflow.ops[op].input_partitioning.len()];
        for e in self.workflow.in_edges(op) {
            counts[e.to_port] += self.live_workers_of(e.from);
        }
        counts
    }

    /// `End`s a worker of `op` spawned *now* will never receive: one per
    /// already-completed upstream worker (those sent `End` to the old
    /// receiver set only).
    fn missed_ends(&self, op: usize) -> Vec<usize> {
        let mut counts = vec![0usize; self.workflow.ops[op].input_partitioning.len()];
        for e in self.workflow.in_edges(op) {
            counts[e.to_port] +=
                self.completed.iter().filter(|w| w.op == e.from).count();
        }
        counts
    }

    /// Change `op`'s parallelism to `new_n` inside one fenced epoch:
    ///
    /// 1. **Fence** — pause every worker and await all acks; upstream
    ///    senders flush on pause, so all in-flight data is parked in
    ///    receiver channels/stashes. The fence bumps the worker-set
    ///    epoch stamped into `RescaleSelf` and spawned workers.
    /// 2. **Unplug** — each old worker of `op` surrenders its operator
    ///    state, unprocessed input, operator-buffered input and — on
    ///    scan workers — its live `TupleSource`
    ///    (`ExtractScaleState` → `ScaleState`). Broadcast-input
    ///    operators take the replicate/retire path
    ///    ([`Coordinator::scale_broadcast`]) instead.
    /// 3. **Retire/spawn** — worker threads + mailboxes are destroyed or
    ///    created; range bounds are recomputed for the new receiver
    ///    set; surrendered scan ranges are repartitioned over the new
    ///    worker set ([`redistribute_sources`]: stride splits on
    ///    scale-up, chains on scale-down).
    /// 4. **Re-hash** — every surrendered state shard is split by
    ///    `scope % new_n` and installed on its new owner; surrendered
    ///    input is re-routed through a fresh partitioner; surviving
    ///    scan workers get their repartitioned range (`InstallSource`).
    /// 5. **Rewire** — upstream partitioners swap to the new receiver
    ///    set, siblings swap peer senders + barrier epoch, downstream
    ///    EOF accounting updates.
    /// 6. **Resume** — unless the driver had explicitly paused.
    ///
    /// Refused (returns `Duration::ZERO`) for operators with completed
    /// workers (the EOF cascade is already under way) and for unknown
    /// ops / unchanged counts. Source, scatter-merge and
    /// broadcast-input operators — refused before universal elasticity
    /// — now scale through the same fence (splittable scan ranges, the
    /// epoch-keyed peer barrier, and build-side replication
    /// respectively).
    fn do_scale(&mut self, op: usize, new_n: usize) -> Duration {
        let t0 = Instant::now();
        if self.shutdown
            || op >= self.workflow.ops.len()
            || new_n == 0
            || new_n == self.workflow.ops[op].workers
            || self.completed.iter().any(|w| w.op == op)
            || !self.pending_failures.is_empty()
        {
            return Duration::ZERO;
        }
        let old_n = self.workflow.ops[op].workers;
        let is_source = self.workflow.ops[op].is_source;
        let broadcast_ports: Vec<usize> = self.workflow.ops[op]
            .input_partitioning
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, PartitionScheme::Broadcast))
            .map(|(p, _)| p)
            .collect();
        let deadline = Instant::now() + Duration::from_secs(30);

        // Let any in-flight pause/checkpoint handshake settle first so
        // the fence does not interleave with it.
        while (self.checkpoint_reply.is_some()
            || !self.snapshot_outstanding.is_empty()
            || !self.pause_outstanding.is_empty())
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }

        // (1) Fence: pause-all, await acks (completed workers ack too).
        // A worker declared failed mid-fence can never ack: abort the
        // fence immediately (recovery runs back on the run loop).
        self.pause_outstanding = self.handles.keys().copied().collect();
        self.broadcast_all(ControlMessage::Pause);
        while !self.pause_outstanding.is_empty()
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        // Abort (nothing has been touched yet) if the fence could not
        // close: a worker failed to ack in time (or failed outright), or
        // a target worker completed between the guard check and the
        // fence closing (its results are already emitted, so the epoch
        // can't be exact).
        if !self.pause_outstanding.is_empty()
            || !self.pending_failures.is_empty()
            || self.completed.iter().any(|w| w.op == op)
        {
            self.pause_outstanding.clear();
            self.abort_scale();
            return Duration::ZERO;
        }
        // Worker-set version for this fence: scatter-merge peer
        // barriers and spawned workers are keyed on it.
        self.fence_epoch += 1;
        let epoch = self.fence_epoch;

        // Broadcast-input operators replicate the build side instead of
        // re-hashing it; their protocol differs from here on.
        if !broadcast_ports.is_empty() {
            return self.scale_broadcast(op, new_n, &broadcast_ports, epoch, t0, deadline);
        }

        // (2) Unplug the old worker set (scan workers also surrender
        // their live sources).
        self.scale_collect.clear();
        let old_ids: Vec<WorkerId> = (0..old_n)
            .map(|w| WorkerId::new(op, w))
            .filter(|id| self.handles.contains_key(id))
            .collect();
        for id in &old_ids {
            self.send_control(
                *id,
                ControlMessage::ExtractScaleState {
                    replicate: false,
                    partitioned_only: false,
                    preserve_routing: false,
                },
            );
        }
        while self.scale_collect.len() < old_ids.len()
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        // Abort-and-restore if any worker failed to surrender in time
        // (or failed outright): hand every collected
        // state/pending/source back to its original owner rather than
        // proceed with a partial (silently lossy) epoch.
        if self.scale_collect.len() < old_ids.len() || !self.pending_failures.is_empty() {
            self.abort_scale();
            return Duration::ZERO;
        }
        let mut collected: Vec<(WorkerId, ScaleSurrender)> =
            self.scale_collect.drain().collect();
        collected.sort_by_key(|(id, _)| *id);

        // (3) Update the plan-time facts: worker count and range bounds.
        self.update_plan_facts(op, new_n);
        // Source ops: repartition the surrendered scan-range remainders
        // over the new worker set — stride splits on scale-up, chained
        // remainders on scale-down. The multiset union of the new
        // ranges equals the union of the remainders, and every range is
        // itself deterministic/seekable, so replay stays byte-stable.
        let mut new_sources: Vec<Option<Box<dyn TupleSource>>> = if is_source {
            let srcs: Vec<Box<dyn TupleSource>> = collected
                .iter_mut()
                .filter_map(|(_, s)| s.source.take())
                .collect();
            redistribute_sources(srcs, new_n)
                .into_iter()
                .map(Some)
                .collect()
        } else {
            (0..new_n).map(|_| None).collect()
        };
        // Retire surplus workers (none completed — guarded above), or
        // create mailboxes + spawn threads for the new ones. New workers
        // start paused and join the closing Resume with everyone else.
        if new_n < old_n {
            for w in new_n..old_n {
                let id = WorkerId::new(op, w);
                self.send_control(id, ControlMessage::Die);
                if let Some(mut h) = self.handles.remove(&id) {
                    if let Some(t) = h.thread.take() {
                        let _ = t.join();
                    }
                    self.total_workers -= 1;
                }
                self.senders.remove(&id);
            }
        } else {
            let mut mailboxes = Vec::new();
            for w in old_n..new_n {
                let id = WorkerId::new(op, w);
                let (tx, mb) = mailbox(self.config.data_queue_cap);
                self.senders.insert(id, tx);
                mailboxes.push((w, mb));
            }
            for (w, mb) in mailboxes {
                let src = new_sources[w].take();
                self.spawn_scaled_worker(op, w, mb, src, epoch);
                self.total_workers += 1;
            }
        }
        // Surviving scan workers swap to their repartitioned ranges.
        if is_source {
            for (w, slot) in new_sources
                .into_iter()
                .enumerate()
                .take(old_n.min(new_n))
            {
                if let Some(src) = slot {
                    self.send_control(
                        WorkerId::new(op, w),
                        ControlMessage::InstallSource(
                            crate::engine::message::source_slot(src),
                        ),
                    );
                }
            }
        }
        let schemes = self.workflow.ops[op].input_partitioning.clone();

        // (4a) Re-hash the surrendered state. Shards are split per
        // source worker and merged by the *operator* on the receiving
        // side (`install_state`), so kind-aware combination (min/max,
        // avg pairs, sorted runs) stays with the operator.
        let mut pending_events: Vec<(WorkerId, Vec<DataEvent>)> = Vec::new();
        for (id, surrender) in collected {
            self.install_state_shards(op, new_n, surrender.state);
            pending_events.push((id, surrender.pending));
        }
        // (4b) Re-route the surrendered input through a fresh
        // partitioner per port. In-flight migrated state merges like
        // extracted state; stale epoch markers are dropped (overlays
        // are cleared below); an `End` surrendered by a survivor is
        // re-delivered to that same survivor — its per-port EOF count
        // is still expecting it.
        let mut routers: Vec<Partitioner> = schemes
            .iter()
            .map(|s| Partitioner::new(s.clone(), new_n, 0))
            .collect();
        let mut ends: Vec<(WorkerId, DataEvent)> = Vec::new();
        let mut batches: Vec<Vec<Vec<Tuple>>> =
            vec![vec![Vec::new(); schemes.len()]; new_n];
        for (src, pending) in pending_events {
            for ev in pending {
                match ev {
                    DataEvent::Batch(msg) => {
                        for t in msg.batch.iter() {
                            let dest = routers[msg.port].route(t);
                            batches[dest][msg.port].push(t.clone());
                        }
                    }
                    DataEvent::State { state, .. } => {
                        self.install_state_shards(op, new_n, state);
                    }
                    DataEvent::End { from, port } if src.idx < new_n => {
                        ends.push((src, DataEvent::End { from, port }));
                    }
                    _ => {}
                }
            }
        }
        for (dest, ports) in batches.into_iter().enumerate() {
            for (port, tuples) in ports.into_iter().enumerate() {
                if tuples.is_empty() {
                    continue;
                }
                let _ = self.senders[&WorkerId::new(op, dest)].send(DataEvent::Batch(
                    DataMessage {
                        from: WorkerId::new(op, dest),
                        port,
                        seq: 0,
                        batch: tuples.into(),
                        hashes: None,
                    },
                ));
            }
        }
        for (to, ev) in ends {
            let _ = self.senders[&to].send(ev);
        }

        // (5)+(6) Rewire the topology and close the epoch.
        self.rewire_and_resume(op, new_n, epoch, &schemes);
        self.maybe_done();
        self.invalidate_restore_point();
        t0.elapsed()
    }

    /// Scale a **broadcast-input** operator (the fence is already
    /// closed and `epoch` stamped). Every worker of such an operator
    /// holds an identical replica of the broadcast-built state, so the
    /// protocol never moves state between survivors:
    ///
    /// * **Scale-up** — a donor (worker 0) *copies* its broadcast-side
    ///   state ([`crate::engine::operator::Operator::replicate_broadcast_state`])
    ///   and pending input (`ExtractScaleState { replicate: true }`);
    ///   each spawned worker receives the replica (`InstallReplica`)
    ///   plus a clone of the donor's pending **broadcast-port**
    ///   batches. Its view of the broadcast stream then equals the
    ///   donor's — past deliveries in the replica, parked deliveries in
    ///   the cloned pending, future deliveries fanned out by the
    ///   rewired upstream edges. `End` events are never cloned: the
    ///   spawned worker's `initial_eofs` already account for completed
    ///   upstream senders, and live senders will deliver theirs.
    /// * **Scale-down** — only the retiring workers unplug; their
    ///   replica state, broadcast-port pending, and per-receiver `End`
    ///   copies are dropped (every survivor holds its own), while
    ///   non-broadcast pending — hash/RR-partitioned ports, including
    ///   operator-buffered input such as a join's early probes — is
    ///   re-routed to the survivors through a fresh partitioner.
    /// * Both directions additionally **sweep** keyed
    ///   *partitioned-port* state
    ///   ([`crate::engine::operator::Operator::partitioned_state`])
    ///   from every pre-fence worker and re-shard it over the new
    ///   worker set by `hash % n`: mixed-port operators (a broadcast
    ///   dictionary plus hash-partitioned per-key state, e.g.
    ///   [`crate::operators::Enrich`]) keep their keyed state aligned
    ///   with the key→worker routing map, which changes with `n`. For
    ///   broadcast-only-state operators (the hash join this protocol
    ///   was built for) the sweep surrenders empty states and is a
    ///   no-op.
    fn scale_broadcast(
        &mut self,
        op: usize,
        new_n: usize,
        bports: &[usize],
        epoch: u64,
        t0: Instant,
        deadline: Instant,
    ) -> Duration {
        let old_n = self.workflow.ops[op].workers;
        if new_n > old_n {
            // (2) Replicate from a donor (worker 0 is alive: the fence
            // closed with no completed worker of `op`).
            self.scale_collect.clear();
            let donor = WorkerId::new(op, 0);
            self.send_control(
                donor,
                ControlMessage::ExtractScaleState {
                    replicate: true,
                    partitioned_only: false,
                    preserve_routing: false,
                },
            );
            while self.scale_collect.is_empty()
                && self.pending_failures.is_empty()
                && Instant::now() < deadline
            {
                self.pump_fence();
            }
            let Some(surrender) = self.scale_collect.remove(&donor) else {
                // Nothing was surrendered (the donor kept its copy), so
                // the abort only lifts the fence pause.
                self.scale_collect.clear();
                self.abort_scale();
                return Duration::ZERO;
            };
            // (2b) Sweep keyed partitioned-port state from every old
            // worker (a *move*, unlike the donor's copy): its owner map
            // is `hash % n` and n is about to change.
            self.scale_collect.clear();
            let old_ids: Vec<WorkerId> = (0..old_n)
                .map(|w| WorkerId::new(op, w))
                .filter(|id| self.handles.contains_key(id))
                .collect();
            for id in &old_ids {
                self.send_control(
                    *id,
                    ControlMessage::ExtractScaleState {
                        replicate: true,
                        partitioned_only: true,
                        preserve_routing: false,
                    },
                );
            }
            while self.scale_collect.len() < old_ids.len()
                && self.pending_failures.is_empty()
                && Instant::now() < deadline
            {
                self.pump_fence();
            }
            if self.scale_collect.len() < old_ids.len() || !self.pending_failures.is_empty()
            {
                // Restore the swept shards we did get (the donor's
                // replicate was a copy; nothing else has moved).
                self.abort_scale();
                return Duration::ZERO;
            }
            let mut swept: Vec<(WorkerId, ScaleSurrender)> =
                self.scale_collect.drain().collect();
            swept.sort_by_key(|(id, _)| *id);
            self.update_plan_facts(op, new_n);
            let mut mailboxes = Vec::new();
            for w in old_n..new_n {
                let id = WorkerId::new(op, w);
                let (tx, mb) = mailbox(self.config.data_queue_cap);
                self.senders.insert(id, tx);
                mailboxes.push((w, mb));
            }
            for (w, mb) in mailboxes {
                self.spawn_scaled_worker(op, w, mb, None, epoch);
                self.total_workers += 1;
            }
            // (4) Replicate the build side + parked broadcast input.
            for w in old_n..new_n {
                let id = WorkerId::new(op, w);
                if !surrender.state.is_empty() {
                    self.send_control(
                        id,
                        ControlMessage::InstallReplica(surrender.state.clone()),
                    );
                }
                for ev in &surrender.pending {
                    if let DataEvent::Batch(msg) = ev {
                        if bports.contains(&msg.port) {
                            let _ = self.senders[&id].send(DataEvent::Batch(DataMessage {
                                from: msg.from,
                                port: msg.port,
                                seq: 0,
                                batch: msg.batch.clone(),
                                hashes: msg.hashes.clone(),
                            }));
                        }
                    }
                }
            }
            // (4b) Re-shard the swept partitioned-port state over the
            // enlarged worker set.
            for (_, s) in swept {
                if !s.state.is_empty() {
                    self.install_state_shards(op, new_n, s.state);
                }
            }
        } else {
            // (2) Unplug the retiring workers (partitioned-port state +
            // parked input; their broadcast replicas are dropped, every
            // survivor holds its own); survivors keep replicas and
            // pending untouched but still *sweep* their keyed
            // partitioned-port state, whose `hash % n` owner map is
            // about to change.
            self.scale_collect.clear();
            let retiring: Vec<WorkerId> = (new_n..old_n)
                .map(|w| WorkerId::new(op, w))
                .filter(|id| self.handles.contains_key(id))
                .collect();
            let surviving: Vec<WorkerId> = (0..new_n)
                .map(|w| WorkerId::new(op, w))
                .filter(|id| self.handles.contains_key(id))
                .collect();
            for id in &retiring {
                self.send_control(
                    *id,
                    ControlMessage::ExtractScaleState {
                        replicate: false,
                        partitioned_only: true,
                        preserve_routing: false,
                    },
                );
            }
            for id in &surviving {
                self.send_control(
                    *id,
                    ControlMessage::ExtractScaleState {
                        replicate: true,
                        partitioned_only: true,
                        preserve_routing: false,
                    },
                );
            }
            let expected = retiring.len() + surviving.len();
            while self.scale_collect.len() < expected
                && self.pending_failures.is_empty()
                && Instant::now() < deadline
            {
                self.pump_fence();
            }
            if self.scale_collect.len() < expected || !self.pending_failures.is_empty() {
                self.abort_scale();
                return Duration::ZERO;
            }
            let mut collected: Vec<(WorkerId, ScaleSurrender)> =
                self.scale_collect.drain().collect();
            collected.sort_by_key(|(id, _)| *id);
            self.update_plan_facts(op, new_n);
            for w in new_n..old_n {
                let id = WorkerId::new(op, w);
                self.send_control(id, ControlMessage::Die);
                if let Some(mut h) = self.handles.remove(&id) {
                    if let Some(t) = h.thread.take() {
                        let _ = t.join();
                    }
                    self.total_workers -= 1;
                }
                self.senders.remove(&id);
            }
            // (4) Re-shard every surrendered partitioned-port state
            // shard (retirees *and* survivor sweeps) over the survivor
            // set, and re-route the retirees' non-broadcast pending
            // through the freshly recomputed schemes; broadcast
            // replicas are dropped.
            let schemes = self.workflow.ops[op].input_partitioning.clone();
            let mut routers: Vec<Partitioner> = schemes
                .iter()
                .map(|s| Partitioner::new(s.clone(), new_n, 0))
                .collect();
            let mut batches: Vec<Vec<Vec<Tuple>>> =
                vec![vec![Vec::new(); schemes.len()]; new_n];
            for (_, surrender) in collected {
                if !surrender.state.is_empty() {
                    self.install_state_shards(op, new_n, surrender.state);
                }
                for ev in surrender.pending {
                    if let DataEvent::Batch(msg) = ev {
                        if bports.contains(&msg.port) {
                            continue;
                        }
                        for t in msg.batch.iter() {
                            let dest = routers[msg.port].route(t);
                            batches[dest][msg.port].push(t.clone());
                        }
                    }
                }
            }
            for (dest, ports) in batches.into_iter().enumerate() {
                for (port, tuples) in ports.into_iter().enumerate() {
                    if tuples.is_empty() {
                        continue;
                    }
                    let _ = self.senders[&WorkerId::new(op, dest)].send(DataEvent::Batch(
                        DataMessage {
                            from: WorkerId::new(op, dest),
                            port,
                            seq: 0,
                            batch: tuples.into(),
                            hashes: None,
                        },
                    ));
                }
            }
        }
        let schemes = self.workflow.ops[op].input_partitioning.clone();
        self.rewire_and_resume(op, new_n, epoch, &schemes);
        self.maybe_done();
        self.invalidate_restore_point();
        t0.elapsed()
    }

    /// Scale fence step (3), plan-fact half: set the new worker count
    /// and recompute Range partition bounds for the resized receiver
    /// set. Shared by the generic and broadcast fence paths (a
    /// broadcast-input operator may still have a Range-partitioned
    /// other port).
    fn update_plan_facts(&mut self, op: usize, new_n: usize) {
        self.workflow.ops[op].workers = new_n;
        for scheme in self.workflow.ops[op].input_partitioning.iter_mut() {
            if let PartitionScheme::Range { bounds, .. } = scheme {
                let nb = crate::engine::scale::rescale_bounds(bounds, new_n);
                *bounds = nb;
            }
        }
    }

    /// Scale fence steps (5)+(6): swap the scaled operator's sibling
    /// senders and worker-set epoch (`RescaleSelf`), rebuild upstream
    /// partitioners against the new receiver set (`RescaleEdge`),
    /// rewrite downstream EOF expectations (`UpdateUpstreamCount`), and
    /// lift the fence pause. `FenceResume` undoes only the fence's
    /// pause, so a worker that was parked at a breakpoint or a
    /// global-breakpoint target before the fence stays parked.
    fn rewire_and_resume(
        &mut self,
        op: usize,
        new_n: usize,
        epoch: u64,
        schemes: &[PartitionScheme],
    ) {
        let new_senders: Vec<DataSender> = (0..new_n)
            .map(|w| self.senders[&WorkerId::new(op, w)].clone())
            .collect();
        for w in 0..new_n {
            self.send_control(
                WorkerId::new(op, w),
                ControlMessage::RescaleSelf {
                    peers: new_senders.clone(),
                    workers: new_n,
                    epoch,
                },
            );
        }
        let mut upstream_ops: Vec<usize> =
            self.workflow.in_edges(op).iter().map(|e| e.from).collect();
        upstream_ops.sort_unstable();
        upstream_ops.dedup();
        let up_workers: Vec<WorkerId> = self
            .handles
            .keys()
            .filter(|w| upstream_ops.contains(&w.op))
            .copied()
            .collect();
        for id in up_workers {
            self.send_control(
                id,
                ControlMessage::RescaleEdge {
                    target_op: op,
                    receivers: new_n,
                    port_schemes: schemes.to_vec(),
                    senders: new_senders.clone(),
                },
            );
        }
        let downstream: Vec<(usize, usize)> = self
            .workflow
            .out_edges(op)
            .iter()
            .map(|e| (e.to, e.to_port))
            .collect();
        for (dst, port) in downstream {
            let count = self.expected_ends(dst)[port];
            for w in 0..self.workflow.ops[dst].workers {
                self.send_control(
                    WorkerId::new(dst, w),
                    ControlMessage::UpdateUpstreamCount { port, count },
                );
            }
        }
        if !self.user_paused {
            self.broadcast_all(ControlMessage::FenceResume);
        }
    }

    /// Abandon an open fence: return every surrendered state/pending
    /// set (and scan range) to its original owner and lift the fence
    /// pause. Leaves the workflow exactly as before the scale attempt.
    fn abort_scale(&mut self) {
        let collected: Vec<(WorkerId, ScaleSurrender)> =
            self.scale_collect.drain().collect();
        for (id, surrender) in collected {
            if !surrender.state.is_empty() {
                self.send_control(id, ControlMessage::InstallState(surrender.state));
            }
            if let Some(src) = surrender.source {
                self.send_control(
                    id,
                    ControlMessage::InstallSource(crate::engine::message::source_slot(src)),
                );
            }
            if let Some(s) = self.senders.get(&id) {
                for ev in surrender.pending {
                    let _ = s.send(ev);
                }
            }
        }
        if !self.user_paused {
            self.broadcast_all(ControlMessage::FenceResume);
        }
    }

    /// Split one surrendered state by hash owner and install each
    /// non-empty shard on its new worker.
    fn install_state_shards(&self, op: usize, new_n: usize, state: OpState) {
        for (dest, shard) in state.split_by_hash(new_n).into_iter().enumerate() {
            if !shard.is_empty() {
                self.send_control(
                    WorkerId::new(op, dest),
                    ControlMessage::InstallState(shard),
                );
            }
        }
    }

    // ---- live plan migration (engine::migrate) -------------------------

    /// Open a migration fence: let any in-flight pause/checkpoint
    /// handshake settle, then pause-all and await every ack. Returns
    /// `false` (fence already aborted, pause lifted) if the acks do not
    /// arrive by `deadline`.
    fn open_fence(&mut self, deadline: Instant) -> bool {
        while (self.checkpoint_reply.is_some()
            || !self.snapshot_outstanding.is_empty()
            || !self.pause_outstanding.is_empty())
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        self.pause_outstanding = self.handles.keys().copied().collect();
        self.broadcast_all(ControlMessage::Pause);
        while !self.pause_outstanding.is_empty()
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        if !self.pause_outstanding.is_empty() || !self.pending_failures.is_empty() {
            self.pause_outstanding.clear();
            self.abort_scale();
            return false;
        }
        true
    }

    /// Execute a [`PlanDelta`]: plan it into an ordered sequence of
    /// fenced steps ([`crate::engine::migrate::plan`]), apply them in
    /// order, and — if any step's fence refuses or cannot close — roll
    /// the already-applied prefix back with inverse steps (best
    /// effort). Abort-and-restore at the sequence level, mirroring
    /// `abort_scale` at the step level.
    fn do_migrate(&mut self, delta: PlanDelta) -> MigrationOutcome {
        let t0 = Instant::now();
        let steps = match crate::engine::migrate::plan(&self.workflow, &delta) {
            Ok(s) => s,
            Err(e) => {
                return MigrationOutcome {
                    applied: false,
                    rolled_back: false,
                    steps: vec![StepOutcome {
                        desc: format!("refused at plan time: {e}"),
                        fence: Duration::ZERO,
                        applied: false,
                    }],
                    total: t0.elapsed(),
                }
            }
        };
        let mut outcomes = Vec::new();
        let mut undo: Vec<MigrationStep> = Vec::new();
        let mut ok = true;
        for step in steps {
            let desc = step.describe();
            let (d, inverse) = self.apply_step(&step);
            let applied = d > Duration::ZERO;
            outcomes.push(StepOutcome { desc, fence: d, applied });
            if !applied {
                ok = false;
                break;
            }
            if let Some(inv) = inverse {
                undo.push(inv);
            }
        }
        let mut rolled_back = false;
        if !ok && !undo.is_empty() {
            rolled_back = true;
            for inv in undo.into_iter().rev() {
                let desc = format!("rollback: {}", inv.describe());
                let (d, _) = self.apply_step(&inv);
                outcomes.push(StepOutcome {
                    desc,
                    fence: d,
                    applied: d > Duration::ZERO,
                });
            }
        }
        MigrationOutcome {
            applied: ok,
            rolled_back,
            steps: outcomes,
            total: t0.elapsed(),
        }
    }

    /// Apply one migration step; returns its fence duration (zero =
    /// refused/aborted, nothing changed) and the inverse step that
    /// undoes it.
    fn apply_step(&mut self, step: &MigrationStep) -> (Duration, Option<MigrationStep>) {
        match step {
            MigrationStep::Repartition { op, port, scheme } => {
                let old = self
                    .workflow
                    .ops
                    .get(*op)
                    .and_then(|o| o.input_partitioning.get(*port))
                    .cloned();
                let d = self.do_repartition(*op, *port, scheme.clone());
                let inv = old.map(|s| MigrationStep::Repartition {
                    op: *op,
                    port: *port,
                    scheme: s,
                });
                (d, inv)
            }
            MigrationStep::InsertMat { from, to, to_port } => {
                let d = self.do_insert_mat(*from, *to, *to_port);
                (
                    d,
                    Some(MigrationStep::RemoveMat {
                        from: *from,
                        to: *to,
                        to_port: *to_port,
                    }),
                )
            }
            MigrationStep::RemoveMat { from, to, to_port } => {
                let d = self.do_remove_mat(*from, *to, *to_port);
                (
                    d,
                    Some(MigrationStep::InsertMat {
                        from: *from,
                        to: *to,
                        to_port: *to_port,
                    }),
                )
            }
            MigrationStep::Scale { op, workers } => {
                // Same ownership/veto guard as `Command::Scale`.
                if matches!(self.scale_owner.get(op), Some(ScaleOwner::Plugin)) {
                    return (Duration::ZERO, None);
                }
                let old = self.workflow.ops.get(*op).map(|o| o.workers);
                let d = self.do_scale(*op, *workers);
                if d > Duration::ZERO {
                    self.scale_owner.insert(*op, ScaleOwner::Driver);
                }
                (d, old.map(|n| MigrationStep::Scale { op: *op, workers: n }))
            }
        }
    }

    /// Migration step: swap the partitioning scheme on input `port` of
    /// `op` under one fence, worker count unchanged.
    ///
    /// The unplug carries `preserve_routing: true` — a promise that the
    /// parked input comes back to the *same* worker set as one
    /// consolidated batch per port, delivered port-ascending, which is
    /// exactly the shape `Worker::remap_replay_positions` needs to keep
    /// control-replay records that straddle the fence exact.
    ///
    /// Keyed-state colocation invariant: state shards live at
    /// `stable_hash(key) % n`, so a worker holding non-empty keyed
    /// state can only keep it colocated with *future* tuples if the
    /// routing stays key-deterministic onto the same owner map. Rather
    /// than guess, a stateful operator (n > 1) aborts-and-restores; the
    /// empty-state case (the common mid-run window before a blocking
    /// port fills, and every stateless operator) migrates freely.
    fn do_repartition(
        &mut self,
        op: usize,
        port: usize,
        new_scheme: PartitionScheme,
    ) -> Duration {
        let t0 = Instant::now();
        if self.shutdown
            || op >= self.workflow.ops.len()
            || port >= self.workflow.ops[op].input_partitioning.len()
            || matches!(new_scheme, PartitionScheme::Broadcast)
            || matches!(
                self.workflow.ops[op].input_partitioning[port],
                PartitionScheme::Broadcast
            )
            || self.completed.iter().any(|w| w.op == op)
            || !self.pending_failures.is_empty()
        {
            return Duration::ZERO;
        }
        let n = self.workflow.ops[op].workers;
        let deadline = Instant::now() + Duration::from_secs(30);
        if !self.open_fence(deadline) {
            return Duration::ZERO;
        }
        if self.completed.iter().any(|w| w.op == op) {
            self.abort_scale();
            return Duration::ZERO;
        }
        self.fence_epoch += 1;
        let epoch = self.fence_epoch;

        // (2) Unplug, with the routing-preserving promise.
        self.scale_collect.clear();
        let ids: Vec<WorkerId> = (0..n)
            .map(|w| WorkerId::new(op, w))
            .filter(|id| self.handles.contains_key(id))
            .collect();
        for id in &ids {
            self.send_control(
                *id,
                ControlMessage::ExtractScaleState {
                    replicate: false,
                    partitioned_only: false,
                    preserve_routing: true,
                },
            );
        }
        while self.scale_collect.len() < ids.len()
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        if self.scale_collect.len() < ids.len() || !self.pending_failures.is_empty() {
            self.abort_scale();
            return Duration::ZERO;
        }
        let mut collected: Vec<(WorkerId, ScaleSurrender)> =
            self.scale_collect.drain().collect();
        collected.sort_by_key(|(id, _)| *id);

        // Colocation invariant (see the doc comment): abort-and-restore
        // for stateful multi-worker operators, where surrendered keyed
        // state (or in-flight scattered state) would come apart from
        // the new routing.
        let stateful = collected.iter().any(|(_, s)| {
            !s.state.is_empty()
                || s.pending
                    .iter()
                    .any(|ev| matches!(ev, DataEvent::State { .. }))
        });
        if stateful && n > 1 {
            self.scale_collect = collected.into_iter().collect();
            self.abort_scale();
            return Duration::ZERO;
        }

        // Commit the plan fact. Empty Range bounds are recomputed from
        // the parked tuples themselves (the migration analogue of
        // `rescale_bounds`, which resizes *existing* bounds).
        let mut scheme = new_scheme;
        if let PartitionScheme::Range { key, bounds } = &mut scheme {
            if bounds.is_empty() && n > 1 {
                let mut sample: Vec<crate::tuple::Value> = Vec::new();
                for (_, s) in &collected {
                    for ev in &s.pending {
                        if let DataEvent::Batch(msg) = ev {
                            if msg.port == port {
                                sample.extend(
                                    msg.batch.iter().map(|t| t.get(*key).clone()),
                                );
                            }
                        }
                    }
                }
                *bounds = crate::engine::migrate::derive_bounds(sample, n);
            }
        }
        self.workflow.ops[op].input_partitioning[port] = scheme;
        let schemes = self.workflow.ops[op].input_partitioning.clone();

        // (4) Same-owner state/source reinstall (n unchanged), then
        // re-route all parked input through partitioners built from the
        // new schemes. Delivery is one consolidated batch per
        // (worker, port), port-ascending — the routing-preserving shape
        // promised to `remap_replay_positions`.
        let mut pending_events: Vec<(WorkerId, Vec<DataEvent>)> = Vec::new();
        for (id, surrender) in collected {
            if !surrender.state.is_empty() {
                self.send_control(id, ControlMessage::InstallState(surrender.state));
            }
            if let Some(src) = surrender.source {
                self.send_control(
                    id,
                    ControlMessage::InstallSource(crate::engine::message::source_slot(src)),
                );
            }
            pending_events.push((id, surrender.pending));
        }
        let mut routers: Vec<Partitioner> = schemes
            .iter()
            .map(|s| Partitioner::new(s.clone(), n, 0))
            .collect();
        let mut ends: Vec<(WorkerId, DataEvent)> = Vec::new();
        let mut batches: Vec<Vec<Vec<Tuple>>> = vec![vec![Vec::new(); schemes.len()]; n];
        for (src, pending) in pending_events {
            for ev in pending {
                match ev {
                    DataEvent::Batch(msg) => {
                        for t in msg.batch.iter() {
                            let dest = routers[msg.port].route(t);
                            batches[dest][msg.port].push(t.clone());
                        }
                    }
                    DataEvent::State { state, .. } => {
                        self.install_state_shards(op, n, state);
                    }
                    DataEvent::End { from, port } if src.idx < n => {
                        ends.push((src, DataEvent::End { from, port }));
                    }
                    _ => {}
                }
            }
        }
        for (dest, ports) in batches.into_iter().enumerate() {
            for (bport, tuples) in ports.into_iter().enumerate() {
                if tuples.is_empty() {
                    continue;
                }
                let _ = self.senders[&WorkerId::new(op, dest)].send(DataEvent::Batch(
                    DataMessage {
                        from: WorkerId::new(op, dest),
                        port: bport,
                        seq: 0,
                        batch: tuples.into(),
                        hashes: None,
                    },
                ));
            }
        }
        for (to, ev) in ends {
            let _ = self.senders[&to].send(ev);
        }

        // (5)+(6) Upstream partitioners rebuild against the new scheme
        // (mitigation overlays reset with them); resume.
        self.rewire_and_resume(op, n, epoch, &schemes);
        self.maybe_done();
        self.invalidate_restore_point();
        t0.elapsed()
    }

    /// Migration step: materialize the live edge `from → (to, to_port)`
    /// mid-run. Under one fence, a `MatWriter` op (OneToOne from `u`'s
    /// workers) and a dormant `MatSource` reader op are spliced into
    /// the plan, `u`'s output edge is retargeted onto the writer, and
    /// `v`'s EOF accounting moves to the reader. Tuples already
    /// delivered to `v` pre-fence bypass the store harmlessly — the
    /// sink multiset is preserved; the store captures the post-fence
    /// suffix of the edge. The reader starts only when the last writer
    /// worker completes (`pending_mat_activations`).
    fn do_insert_mat(&mut self, from: usize, to: usize, to_port: usize) -> Duration {
        let t0 = Instant::now();
        let edge = Edge { from, to, to_port };
        if self.shutdown
            || !self.workflow.edges.contains(&edge)
            || self
                .live_mats
                .iter()
                .any(|m| m.from == from && m.to == to && m.to_port == to_port)
            || self.completed.iter().any(|w| w.op == from || w.op == to)
            || !self.pending_failures.is_empty()
        {
            return Duration::ZERO;
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        if !self.open_fence(deadline) {
            return Duration::ZERO;
        }
        if self.completed.iter().any(|w| w.op == from || w.op == to) {
            self.abort_scale();
            return Duration::ZERO;
        }
        self.fence_epoch += 1;
        let epoch = self.fence_epoch;

        // Splice writer + reader ops into the plan (indices are
        // append-only: retired ops keep their slot so `WorkerId.op`
        // stays stable).
        let store = crate::maestro::materialize::MatStore::new();
        let u_workers = self.workflow.ops[from].workers;
        let writer = self.workflow.ops.len();
        let reader = writer + 1;
        let s2 = store.clone();
        self.workflow.ops.push(OpSpec::unary(
            &format!("mig_mat_writer_{from}_{to}_{to_port}"),
            u_workers,
            PartitionScheme::OneToOne,
            move |_, _| Box::new(crate::maestro::materialize::MatWriter::new(s2.clone())),
        ));
        let s3 = store.clone();
        self.workflow.ops.push(OpSpec::source(
            &format!("mig_mat_reader_{from}_{to}_{to_port}"),
            u_workers,
            move |idx, parts| {
                Box::new(crate::maestro::materialize::MatSource::new(
                    s3.clone(),
                    parts,
                    idx,
                ))
            },
        ));
        for e in self.workflow.edges.iter_mut() {
            if *e == edge {
                *e = Edge { from, to: writer, to_port: 0 };
            }
        }
        self.workflow.edges.push(Edge { from: reader, to, to_port });
        self.dormant_ops.insert(reader);
        self.pending_mat_activations.insert(writer, reader);

        // Spawn writer and reader workers (paused; they join the
        // closing FenceResume). The reader workers get their store
        // partition directly but stay dormant (`dormant_ops`).
        for opx in [writer, reader] {
            let mut mbs = Vec::new();
            for w in 0..u_workers {
                let id = WorkerId::new(opx, w);
                let (tx, mb) = mailbox(self.config.data_queue_cap);
                self.senders.insert(id, tx);
                mbs.push((w, mb));
            }
            for (w, mb) in mbs {
                let src: Option<Box<dyn TupleSource>> = if opx == reader {
                    Some(Box::new(crate::maestro::materialize::MatSource::new(
                        store.clone(),
                        u_workers,
                        w,
                    )))
                } else {
                    None
                };
                self.spawn_scaled_worker(opx, w, mb, src, epoch);
                self.total_workers += 1;
            }
        }

        // Retarget u's output edge onto the writer; move v's EOF
        // accounting on that port to the (future) reader Ends.
        let writer_senders: Vec<DataSender> = (0..u_workers)
            .map(|w| self.senders[&WorkerId::new(writer, w)].clone())
            .collect();
        for w in 0..self.workflow.ops[from].workers {
            self.send_control(
                WorkerId::new(from, w),
                ControlMessage::RetargetEdge {
                    old_target: to,
                    old_port: to_port,
                    new_target: writer,
                    new_port: 0,
                    receivers: u_workers,
                    scheme: PartitionScheme::OneToOne,
                    senders: writer_senders.clone(),
                },
            );
        }
        let count = self.expected_ends(to)[to_port];
        for w in 0..self.workflow.ops[to].workers {
            self.send_control(
                WorkerId::new(to, w),
                ControlMessage::UpdateUpstreamCount { port: to_port, count },
            );
        }
        self.live_mats.push(LiveMat { from, to, to_port, writer, reader, store });
        if !self.user_paused {
            self.broadcast_all(ControlMessage::FenceResume);
        }
        self.invalidate_restore_point();
        t0.elapsed()
    }

    /// Migration step: remove a live materialization previously spliced
    /// by [`Coordinator::do_insert_mat`], restoring the direct edge.
    /// Refused once the writer has completed (the reader *is* the live
    /// stream then — removing it would drop the store's contents).
    /// Under one fence the writer workers unplug (parked input plus the
    /// writer's unflushed tail, surrendered via
    /// `MatWriter::drain_buffered_input`), `u` is retargeted back onto
    /// `v`, writer and reader retire, and the store contents plus the
    /// surrendered pending are re-routed to `v` through `v`'s own
    /// scheme — every tuple reaches `v` exactly once: pre-insert
    /// directly, in-store via re-injection, post-remove directly.
    fn do_remove_mat(&mut self, from: usize, to: usize, to_port: usize) -> Duration {
        let t0 = Instant::now();
        let Some(mi) = self
            .live_mats
            .iter()
            .position(|m| m.from == from && m.to == to && m.to_port == to_port)
        else {
            return Duration::ZERO;
        };
        let lm = self.live_mats[mi].clone();
        if self.shutdown
            || self.started_sources.contains(&lm.reader)
            || !self.pending_failures.is_empty()
            || self
                .completed
                .iter()
                .any(|w| w.op == from || w.op == lm.writer || w.op == lm.reader)
        {
            return Duration::ZERO;
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        if !self.open_fence(deadline) {
            return Duration::ZERO;
        }
        if self
            .completed
            .iter()
            .any(|w| w.op == from || w.op == lm.writer)
        {
            self.abort_scale();
            return Duration::ZERO;
        }
        self.fence_epoch += 1;

        // (2) Unplug the writer workers.
        self.scale_collect.clear();
        let writer_ids: Vec<WorkerId> = (0..self.workflow.ops[lm.writer].workers)
            .map(|w| WorkerId::new(lm.writer, w))
            .filter(|id| self.handles.contains_key(id))
            .collect();
        for id in &writer_ids {
            self.send_control(
                *id,
                ControlMessage::ExtractScaleState {
                    replicate: false,
                    partitioned_only: false,
                    preserve_routing: false,
                },
            );
        }
        while self.scale_collect.len() < writer_ids.len()
            && self.pending_failures.is_empty()
            && Instant::now() < deadline
        {
            self.pump_fence();
        }
        if self.scale_collect.len() < writer_ids.len() || !self.pending_failures.is_empty()
        {
            self.abort_scale();
            return Duration::ZERO;
        }
        let mut collected: Vec<(WorkerId, ScaleSurrender)> =
            self.scale_collect.drain().collect();
        collected.sort_by_key(|(id, _)| *id);

        // Retarget u back onto v before retiring the writer.
        let v_scheme = self.workflow.ops[to].input_partitioning[to_port].clone();
        let v_n = self.workflow.ops[to].workers;
        let v_senders: Vec<DataSender> = (0..v_n)
            .map(|w| self.senders[&WorkerId::new(to, w)].clone())
            .collect();
        for w in 0..self.workflow.ops[from].workers {
            self.send_control(
                WorkerId::new(from, w),
                ControlMessage::RetargetEdge {
                    old_target: lm.writer,
                    old_port: 0,
                    new_target: to,
                    new_port: to_port,
                    receivers: v_n,
                    scheme: v_scheme.clone(),
                    senders: v_senders.clone(),
                },
            );
        }

        // Retire writer and reader workers; their op slots stay (worker
        // indices must remain stable) with a zero worker count.
        for opx in [lm.writer, lm.reader] {
            for w in 0..self.workflow.ops[opx].workers {
                let id = WorkerId::new(opx, w);
                self.send_control(id, ControlMessage::Die);
                if let Some(mut h) = self.handles.remove(&id) {
                    if let Some(t) = h.thread.take() {
                        let _ = t.join();
                    }
                    self.total_workers -= 1;
                }
                self.senders.remove(&id);
            }
            self.workflow.ops[opx].workers = 0;
        }
        self.workflow.edges.retain(|e| {
            !(e.from == from && e.to == lm.writer)
                && !(e.from == lm.reader && e.to == to)
        });
        self.workflow.edges.push(Edge { from, to, to_port });

        // Re-inject the store contents, then the surrendered pending
        // (store rows were emitted by u strictly before the parked
        // ones), through v's own scheme.
        let mut router = Partitioner::new(v_scheme, v_n, 0);
        let mut batches: Vec<Vec<Tuple>> = vec![Vec::new(); v_n];
        for t in lm.store.take_all() {
            let dest = router.route(&t);
            batches[dest].push(t);
        }
        for (_, surrender) in collected {
            for ev in surrender.pending {
                if let DataEvent::Batch(msg) = ev {
                    for t in msg.batch.iter() {
                        let dest = router.route(t);
                        batches[dest].push(t.clone());
                    }
                }
            }
        }
        for (dest, tuples) in batches.into_iter().enumerate() {
            if tuples.is_empty() {
                continue;
            }
            let _ = self.senders[&WorkerId::new(to, dest)].send(DataEvent::Batch(
                DataMessage {
                    from: WorkerId::new(to, dest),
                    port: to_port,
                    seq: 0,
                    batch: tuples.into(),
                    hashes: None,
                },
            ));
        }

        // v's EOF accounting reverts to u's live workers.
        let count = self.expected_ends(to)[to_port];
        for w in 0..v_n {
            self.send_control(
                WorkerId::new(to, w),
                ControlMessage::UpdateUpstreamCount { port: to_port, count },
            );
        }
        self.live_mats.remove(mi);
        self.dormant_ops.remove(&lm.reader);
        self.pending_mat_activations.remove(&lm.writer);
        if !self.user_paused {
            self.broadcast_all(ControlMessage::FenceResume);
        }
        self.maybe_done();
        self.invalidate_restore_point();
        t0.elapsed()
    }

    /// Spawn one additional worker of `op` mid-run (scale-up). Mirrors
    /// the deploy-time spawn in `start_inner`, but computes upstream
    /// EOF accounting from the *live* worker sets, seeds the EOFs the
    /// new worker can never receive from already-completed upstream
    /// workers, stamps the fence's worker-set `epoch` (scatter-merge
    /// barrier), hands scale-spawned *scan* workers their repartitioned
    /// range, and inherits the operator's current started/dormant
    /// source status (Maestro deploys sources dormant).
    fn spawn_scaled_worker(
        &mut self,
        op_idx: usize,
        w: usize,
        mb: Mailbox,
        source: Option<Box<dyn TupleSource>>,
        epoch: u64,
    ) {
        let spec = &self.workflow.ops[op_idx];
        let new_n = spec.workers;
        let id = WorkerId::new(op_idx, w);
        let mut outputs = Vec::new();
        for e in self.workflow.out_edges(op_idx) {
            let dst = &self.workflow.ops[e.to];
            let scheme = dst.input_partitioning[e.to_port].clone();
            let dst_senders: Vec<DataSender> = (0..dst.workers)
                .map(|d| self.senders[&WorkerId::new(e.to, d)].clone())
                .collect();
            outputs.push(
                OutputEdge::new(
                    e.to,
                    e.to_port,
                    Partitioner::new(scheme, dst.workers, w),
                    dst_senders,
                )
                .with_columnar(self.config.columnar),
            );
        }
        let peers: Vec<DataSender> = (0..new_n)
            .filter_map(|i| self.senders.get(&WorkerId::new(op_idx, i)).cloned())
            .collect();
        let port_key_fields: Vec<Option<usize>> = spec
            .input_partitioning
            .iter()
            .map(|s| match s {
                PartitionScheme::Hash { key } => Some(*key),
                PartitionScheme::Range { key, .. } => Some(*key),
                _ => None,
            })
            .collect();
        let control = mb.control.clone();
        let gauges = mb.gauges.clone();
        let source_autostart = (self.sources_autostart
            || self.started_sources.contains(&op_idx))
            && !self.dormant_ops.contains(&op_idx);
        let ctx = WorkerContext {
            id,
            mailbox: mb,
            event_tx: self.ev_tx.clone(),
            outputs,
            upstream_counts: self.expected_ends(op_idx),
            peers,
            port_key_fields,
            source,
            source_autostart,
            batch_size: self.config.batch_size,
            ctrl_check_interval: self.config.ctrl_check_interval,
            ft_log: self.config.ft_log,
            snapshot: None,
            scatter_merge: spec.scatter_merge,
            scale_epoch: epoch,
            initial_eofs: Some(self.missed_ends(op_idx)),
            start_paused: true,
            columnar: self.config.columnar,
            fault_plan: self.config.fault_plan.clone(),
            spill: self.spill.clone(),
        };
        let builder = spec.builder.clone();
        let thread = std::thread::Builder::new()
            .name(format!("{}", id))
            .spawn(move || run_worker(ctx, builder(w, new_n)))
            .expect("spawn scaled worker");
        self.handles
            .insert(id, WorkerHandle { control, gauges, thread: Some(thread) });
        // Inherit the operator's armed local breakpoint, if any — the
        // original SetLocalBreakpoint broadcast predates this worker.
        if let Some(pred) = self.local_bps.get(&op_idx).cloned() {
            if pred.is_some() {
                self.send_control(id, ControlMessage::SetLocalBreakpoint(pred));
            }
        }
    }

    /// A successful scale/migration fence changed the plan (worker
    /// counts, partitioning, or topology), so a checkpoint keyed to the
    /// old worker set cannot be restored onto the new one. Recovery
    /// falls back to a scratch redeploy — a full deterministic re-run
    /// with the control-replay log — until the next checkpoint
    /// completes against the new plan.
    fn invalidate_restore_point(&mut self) {
        self.latest_checkpoint = None;
    }

    /// Heartbeat sweep: every worker stamps `WorkerGauges::heartbeat`
    /// from its run loop; a counter that has not moved for
    /// `heartbeat_timeout_ms` declares the worker failed (stall). This
    /// catches livelock/deadlock-class failures that `catch_unwind`
    /// containment (crash-class, reported via `WorkerFailed`) cannot.
    fn sweep_heartbeats(&mut self) {
        let timeout_ms = self.config.heartbeat_timeout_ms;
        if timeout_ms == 0 || self.done_at.is_some() || self.shutdown {
            return;
        }
        let now = Instant::now();
        let timeout = Duration::from_millis(timeout_ms);
        let mut stalled: Vec<(WorkerId, Duration)> = Vec::new();
        for (id, h) in &self.handles {
            let hb = h.gauges.heartbeat.load(Ordering::Relaxed);
            let e = self.last_beats.entry(*id).or_insert((hb, now));
            if hb != e.0 {
                *e = (hb, now);
            } else if now.duration_since(e.1) >= timeout {
                stalled.push((*id, now.duration_since(e.1)));
                // Re-arm, so a declared stall is not re-declared on
                // every sweep while recovery is still pending.
                e.1 = now;
            }
        }
        for (id, silence) in stalled {
            self.supervision.stalls_detected += 1;
            self.supervision
                .observe_detection_ms(silence.as_secs_f64() * 1e3);
            self.pending_failures.push((
                id,
                format!("heartbeat silent for {} ms (stall)", silence.as_millis()),
                now,
            ));
        }
    }

    /// Supervision step, run once per coordinator loop iteration:
    /// sweep heartbeats, then act on any declared failure — recover
    /// when fault tolerance is on and retries remain, abort with a
    /// structured error otherwise. Failures observed after completion
    /// or during shutdown are teardown races and are dropped.
    fn check_supervision(&mut self) {
        self.sweep_heartbeats();
        if self.done_at.is_some() || self.shutdown {
            self.pending_failures.clear();
            return;
        }
        if self.pending_failures.is_empty() {
            return;
        }
        let (worker, cause, _) = self.pending_failures[0].clone();
        if !self.config.ft_log {
            self.abort_with(ExecError::Unsupervised { worker, cause });
            return;
        }
        if self.recovery_attempts >= self.config.recovery_max_retries {
            self.supervision.retries_exhausted = true;
            self.abort_with(ExecError::RecoveryExhausted {
                attempts: self.recovery_attempts,
                last_failure: cause,
            });
            return;
        }
        // Attempt counter never resets: a workload that keeps dying is
        // bounded by `recovery_max_retries` total redeploys, after
        // which the run aborts instead of looping forever.
        self.recovery_attempts += 1;
        let backoff = self
            .config
            .recovery_backoff_ms
            .saturating_mul(1u64 << (self.recovery_attempts - 1).min(16));
        if backoff > 0 {
            std::thread::sleep(Duration::from_millis(backoff));
        }
        let t0 = Instant::now();
        self.redeploy();
        self.supervision
            .observe_recovery_ms(t0.elapsed().as_secs_f64() * 1e3);
    }

    /// Automatic recovery (§2.6.2 closed into a loop): tear the whole
    /// actor DAG down, drain stale events from the dead generation,
    /// redeploy every worker at the *current* plan, restore from the
    /// retained checkpoint — or from scratch when none is valid:
    /// default snapshots reset operator state *and* shared sink
    /// handles, so the deterministic computation re-runs cleanly —
    /// re-inject the control-replay log, and resume.
    fn redeploy(&mut self) {
        // (a) Teardown. A panicked worker's mailbox is already gone;
        // peers blocked on its lanes observed the disconnect and exit
        // on `Die`. The DAG is acyclic, so the joins terminate: sinks
        // drain first, unblocking their upstreams in turn. Stalled
        // workers are joined once their stall window elapses.
        self.broadcast_all(ControlMessage::Die);
        for (_, mut h) in self.handles.drain() {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
        self.senders.clear();

        // (b) Drain events the dead generation emitted before dying so
        // stale Completed/Log/WorkerFailed records cannot pollute the
        // rebuilt generation's bookkeeping. The event channel is FIFO
        // through the forwarder thread, so everything the old workers
        // sent precedes this marker.
        self.recovery_epoch += 1;
        let token = self.recovery_epoch;
        let _ = self.ev_tx.send(WorkerEvent::EpochMark { token });
        loop {
            match self.rx.recv_timeout(Duration::from_secs(5)) {
                Ok(CoordMsg::Event(WorkerEvent::EpochMark { token: t })) if t == token => {
                    break
                }
                Ok(CoordMsg::Event(_)) => {}
                Ok(CoordMsg::Cmd(c)) => self.deferred.push(c),
                Err(_) => break,
            }
        }
        self.pending_failures.clear();
        self.last_beats.clear();

        // (c) Reset run bookkeeping. An interrupted driver Pause
        // handshake resolves now (workers respawn paused and stay
        // paused while `user_paused`); an interrupted checkpoint cycle
        // is re-armed against the rebuilt generation below.
        if let Some((reply, t0)) = self.pause_reply.take() {
            let _ = reply.send(t0.elapsed());
        }
        self.pause_outstanding.clear();
        self.snapshot_outstanding.clear();
        self.snapshot_acc = Checkpoint::default();
        self.auto_checkpoint = false;
        self.scale_collect.clear();
        self.completed.clear();
        self.final_stats.clear();
        self.ops_completed.clear();
        self.port_completed.clear();
        self.total_workers = self.workflow.total_workers();

        // (d) Restore set: duplicate the retained checkpoint so a
        // later attempt can restore it again. Without one, every
        // worker gets a default snapshot — fresh operator state,
        // shared sinks reset to empty — and sources rebuild from their
        // builders, so the full re-run is byte-exact by determinism.
        let mut cp = self
            .latest_checkpoint
            .as_ref()
            .map(|c| c.duplicate())
            .unwrap_or_default();

        // (e) Rebuild mailboxes, then spawn every worker of the
        // current plan (paused).
        let mut mailboxes: HashMap<WorkerId, Mailbox> = HashMap::new();
        for (op_idx, op) in self.workflow.ops.iter().enumerate() {
            for w in 0..op.workers {
                let id = WorkerId::new(op_idx, w);
                let (tx, mb) = mailbox(self.config.data_queue_cap);
                self.senders.insert(id, tx);
                mailboxes.insert(id, mb);
            }
        }
        for op_idx in 0..self.workflow.ops.len() {
            for w in 0..self.workflow.ops[op_idx].workers {
                let id = WorkerId::new(op_idx, w);
                let mb = mailboxes.remove(&id).unwrap();
                let snap = cp.workers.remove(&id).unwrap_or_default();
                self.respawn_worker(op_idx, w, mb, snap);
            }
        }

        // (f) Re-inject the per-worker control-replay log (§2.6.2).
        // Replayed messages are not re-logged, so the log stays valid
        // for a further recovery; checkpoint completion clears it.
        let ids: Vec<WorkerId> = self.handles.keys().copied().collect();
        for id in ids {
            let recs = self.replay_log.for_worker(id);
            if !recs.is_empty() {
                self.send_control(id, ControlMessage::ReplayLog(recs));
            }
        }

        // (g) Resume. A manual checkpoint interrupted by the failure
        // restarts its quiesce cycle against the new generation.
        if self.checkpoint_reply.is_some() {
            self.begin_pause(None);
        } else if !self.user_paused {
            self.broadcast_all(ControlMessage::Resume);
        }
        if self.config.checkpoint_interval_ms > 0 {
            self.next_checkpoint = Some(
                Instant::now() + Duration::from_millis(self.config.checkpoint_interval_ms),
            );
        }
    }

    /// Respawn one worker during `redeploy`. Mirrors the deploy-time
    /// spawn in `start_inner` — upstream EOF accounting comes from the
    /// *plan* (the whole DAG is being rebuilt, so every upstream worker
    /// is live again; restored-finished workers re-announce completion
    /// from their snapshot without re-sending Ends, and downstream
    /// snapshots already counted those Ends) — but keeps the current
    /// fence epoch and started/dormant source status.
    fn respawn_worker(&mut self, op_idx: usize, w: usize, mb: Mailbox, snap: WorkerSnapshot) {
        let spec = &self.workflow.ops[op_idx];
        let n = spec.workers;
        let id = WorkerId::new(op_idx, w);
        let mut upstream_counts = vec![0usize; spec.input_partitioning.len()];
        for e in self.workflow.in_edges(op_idx) {
            upstream_counts[e.to_port] += self.workflow.ops[e.from].workers;
        }
        let mut outputs = Vec::new();
        for e in self.workflow.out_edges(op_idx) {
            let dst = &self.workflow.ops[e.to];
            let scheme = dst.input_partitioning[e.to_port].clone();
            let dst_senders: Vec<DataSender> = (0..dst.workers)
                .map(|d| self.senders[&WorkerId::new(e.to, d)].clone())
                .collect();
            outputs.push(
                OutputEdge::new(
                    e.to,
                    e.to_port,
                    Partitioner::new(scheme, dst.workers, w),
                    dst_senders,
                )
                .with_columnar(self.config.columnar),
            );
        }
        let peers: Vec<DataSender> = (0..n)
            .filter_map(|i| self.senders.get(&WorkerId::new(op_idx, i)).cloned())
            .collect();
        let port_key_fields: Vec<Option<usize>> = spec
            .input_partitioning
            .iter()
            .map(|s| match s {
                PartitionScheme::Hash { key } => Some(*key),
                PartitionScheme::Range { key, .. } => Some(*key),
                _ => None,
            })
            .collect();
        let control = mb.control.clone();
        let gauges = mb.gauges.clone();
        let source_autostart = (self.sources_autostart
            || self.started_sources.contains(&op_idx))
            && !self.dormant_ops.contains(&op_idx);
        let ctx = WorkerContext {
            id,
            mailbox: mb,
            event_tx: self.ev_tx.clone(),
            outputs,
            upstream_counts,
            peers,
            port_key_fields,
            source: if spec.is_source {
                Some((spec.source_builder.as_ref().expect("source op without source"))(w, n))
            } else {
                None
            },
            source_autostart,
            batch_size: self.config.batch_size,
            ctrl_check_interval: self.config.ctrl_check_interval,
            ft_log: self.config.ft_log,
            snapshot: Some(snap),
            scatter_merge: spec.scatter_merge,
            scale_epoch: self.fence_epoch,
            initial_eofs: None,
            start_paused: true,
            columnar: self.config.columnar,
            fault_plan: self.config.fault_plan.clone(),
            spill: self.spill.clone(),
        };
        let builder = spec.builder.clone();
        let thread = std::thread::Builder::new()
            .name(format!("{}", id))
            .spawn(move || run_worker(ctx, builder(w, n)))
            .expect("respawn worker");
        self.handles
            .insert(id, WorkerHandle { control, gauges, thread: Some(thread) });
        if let Some(pred) = self.local_bps.get(&op_idx).cloned() {
            if pred.is_some() {
                self.send_control(id, ControlMessage::SetLocalBreakpoint(pred));
            }
        }
    }

    /// Abort the run with a structured error: tear every worker down
    /// and release every waiter. The promise is a clean, observable
    /// abort — `join()` returns a summary carrying the error; nothing
    /// hangs.
    fn abort_with(&mut self, err: ExecError) {
        self.exec_error = Some(err);
        self.pending_failures.clear();
        self.next_checkpoint = None;
        self.broadcast_all(ControlMessage::Die);
        for (_, mut h) in self.handles.drain() {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
        self.senders.clear();
        self.done_at = Some(Instant::now());
        if let Some((reply, t0)) = self.pause_reply.take() {
            let _ = reply.send(t0.elapsed());
        }
        if let Some(reply) = self.checkpoint_reply.take() {
            let _ = reply.send(Checkpoint::default());
        }
        let summary = self.summary();
        for w in self.done_waiters.drain(..) {
            let _ = w.send(summary.clone());
        }
        let ow: Vec<_> = self.ops_waiters.drain(..).collect();
        for (_, reply) in ow {
            let _ = reply.send(());
        }
        let pw: Vec<_> = self.port_waiters.drain(..).collect();
        for (_, _, reply) in pw {
            let _ = reply.send(());
        }
    }

    fn next_deadline(&self) -> Instant {
        let mut d = self.next_tick;
        for bp in self.breakpoints.values() {
            if let Some(dl) = bp.deadline {
                d = d.min(dl);
            }
        }
        if let Some(cp) = self.next_checkpoint {
            d = d.min(cp);
        }
        d
    }

    fn fire_timers(&mut self) {
        let now = Instant::now();
        if self.plugin.is_some() && now >= self.next_tick {
            self.run_plugin_tick();
            let period = self.plugin.as_ref().map(|p| p.period()).unwrap();
            self.next_tick = now + period;
        }
        let due: Vec<u64> = self
            .breakpoints
            .iter()
            .filter(|(_, b)| b.deadline.map(|d| now >= d).unwrap_or(false))
            .map(|(id, _)| *id)
            .collect();
        for id in due {
            if let Some(st) = self.breakpoints.get_mut(&id) {
                st.deadline = None;
                let act = st.machine.on_timeout();
                self.on_bp_action(id, act);
            }
        }
        // Automatic checkpointer: arm a quiesced checkpoint cycle when
        // due, but only when no other pause/snapshot handshake is in
        // flight and no failure is waiting on recovery. When a slot is
        // skipped the deadline stays armed, so the cycle starts as soon
        // as the engine is quiet again.
        if let Some(due) = self.next_checkpoint {
            if self.done_at.is_some() {
                self.next_checkpoint = None;
            } else if now >= due
                && !self.auto_checkpoint
                && !self.user_paused
                && self.checkpoint_reply.is_none()
                && self.pause_reply.is_none()
                && self.pause_outstanding.is_empty()
                && self.snapshot_outstanding.is_empty()
                && self.pending_failures.is_empty()
            {
                self.auto_checkpoint = true;
                self.begin_pause(None);
            }
        }
    }

    fn run(mut self) {
        loop {
            if self.shutdown {
                // Tear down: all workers die; join threads.
                self.broadcast_all(ControlMessage::Die);
                for (_, mut h) in self.handles.drain() {
                    if let Some(t) = h.thread.take() {
                        let _ = t.join();
                    }
                }
                return;
            }
            let deadline = self.next_deadline();
            let timeout = deadline
                .saturating_duration_since(Instant::now())
                .min(Duration::from_millis(50));
            match self.rx.recv_timeout(timeout) {
                Ok(CoordMsg::Cmd(c)) => self.handle_cmd(c),
                Ok(CoordMsg::Event(e)) => self.handle_event(e),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
            self.fire_timers();
            self.check_supervision();
            // Autoscale: execute plugin-requested parallelism changes
            // (one fenced epoch each), then replay commands deferred
            // while the fence was open. Requests for operators the
            // driver (Maestro) already scaled are vetoed (see the
            // ownership guard in `Command::Scale`).
            let reqs: Vec<(usize, usize)> =
                self.scale_requests.borrow_mut().drain(..).collect();
            for (op, n) in reqs {
                if matches!(self.scale_owner.get(&op), Some(ScaleOwner::Driver)) {
                    continue;
                }
                if self.do_scale(op, n) > Duration::ZERO {
                    self.scale_owner.insert(op, ScaleOwner::Plugin);
                }
            }
            self.drain_deferred();
        }
    }
}

//! Out-of-core execution: the memory-budget accountant and the spill
//! file plane shared by the stateful operators and [`MatStore`].
//!
//! Everything upstream of this module used to assume infinite memory:
//! hash join, group-by and sort kept full state resident and
//! `MatStore` was an in-memory `Vec`. This module supplies the three
//! pieces that let them degrade gracefully past a budget
//! (`Config::memory_budget_bytes`, 0 = unbounded):
//!
//! * **[`MemoryBudget`]** — one shared accountant per execution.
//!   Operators charge their resident state through a [`MemLease`]
//!   (RAII: dropping the lease releases the charge, so a panicking
//!   worker can never leak budget). `used`/`high_water` are tracked
//!   even when the limit is 0 so `SpillStats::budget_high_water` is
//!   always meaningful; [`MemoryBudget::over`] is what operators poll
//!   to decide whether to spill.
//! * **[`SpillFile`] / [`SpillReader`]** — the on-disk format: a
//!   sequence of length-prefixed frames, each holding a run of tuples
//!   in the engine's columnar [`ColumnSet`] layout (typed vectors +
//!   validity masks, byte-preserving for floats) with a row-major
//!   fallback for ragged/zero-arity runs. Read-back re-enters the
//!   fast plane as a columnar [`TupleBatch`] without transposition.
//!   Files are **append-only and never deleted mid-run**: checkpoint
//!   manifests ([`SpillSlot`]) reference them by path + byte length,
//!   and recovery reopens them with `set_len(bytes)` — byte-exact
//!   even when the failure struck after further appends.
//! * **[`SpillDir`]** — the per-execution temp directory, created
//!   lazily on first spill and removed recursively when the
//!   execution's last [`SpillCtx`] clone drops (teardown, cancel,
//!   abort — all paths converge on the RAII drop).
//!
//! Partitioned spilling uses hash bits *above* the exchange's routing
//! bits: [`partition_of`] takes 4 bits per recursion depth starting at
//! bit 8, so re-hash scale fences (which consume the low bits) never
//! correlate with spill partitions. See `docs/ARCHITECTURE.md`
//! ("Out-of-core execution") for the full design.
//!
//! [`MatStore`]: crate::maestro::materialize::MatStore
//! [`ColumnSet`]: crate::column::ColumnSet

use crate::column::{Column, ColumnSet};
use crate::config::Config;
use crate::metrics::SpillStats;
use crate::tuple::{Tuple, TupleBatch, Value};
use std::fs::{File, OpenOptions};
use std::io::{BufReader, Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Partition fan-out per recursion level (4 hash bits).
pub const SPILL_FANOUT: usize = 16;

/// Maximum recursion depth for partition spilling; a partition still
/// over budget at this depth is processed in memory regardless (the
/// budget becomes advisory — correctness over strictness).
pub const SPILL_MAX_DEPTH: u32 = 5;

/// The spill partition of hash `h` at recursion `depth`: 4 bits per
/// level starting at bit 8, disjoint from the exchange's low routing
/// bits so rescales don't skew partition sizes.
#[inline]
pub fn partition_of(h: u64, depth: u32) -> usize {
    ((h >> (8 + 4 * depth)) & (SPILL_FANOUT as u64 - 1)) as usize
}

#[derive(Debug, Default)]
struct BudgetInner {
    limit: u64,
    used: AtomicU64,
    high_water: AtomicU64,
}

/// The shared memory accountant for one execution. Cloning shares the
/// counters; `limit == 0` means unbounded (nothing ever reports
/// [`MemoryBudget::over`], but usage is still tracked).
#[derive(Clone, Debug, Default)]
pub struct MemoryBudget {
    inner: Arc<BudgetInner>,
}

impl MemoryBudget {
    pub fn new(limit: u64) -> MemoryBudget {
        MemoryBudget {
            inner: Arc::new(BudgetInner { limit, ..Default::default() }),
        }
    }

    /// The configured limit in bytes (0 = unbounded).
    pub fn limit(&self) -> u64 {
        self.inner.limit
    }

    /// Bytes currently charged across all leases.
    pub fn used(&self) -> u64 {
        self.inner.used.load(Ordering::Relaxed)
    }

    /// High-water mark of [`MemoryBudget::used`] over the execution.
    pub fn high_water(&self) -> u64 {
        self.inner.high_water.load(Ordering::Relaxed)
    }

    /// Whether charged usage currently exceeds a non-zero limit — the
    /// operators' "should I spill now?" poll.
    pub fn over(&self) -> bool {
        self.inner.limit > 0 && self.used() > self.inner.limit
    }

    fn charge(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let now = self.inner.used.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.high_water.fetch_max(now, Ordering::Relaxed);
    }

    fn release(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        // Saturating: a release can never underflow the global gauge
        // (leases only release what they charged, but stay defensive).
        let mut cur = self.inner.used.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.inner.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(v) => cur = v,
            }
        }
    }
}

/// One operator's charge against the shared [`MemoryBudget`]. Call
/// [`MemLease::set`] with the operator's current resident-state bytes
/// after every mutation; dropping the lease (worker teardown, panic
/// unwind) releases the whole charge.
#[derive(Debug, Default)]
pub struct MemLease {
    budget: MemoryBudget,
    charged: u64,
}

impl MemLease {
    pub fn new(budget: MemoryBudget) -> MemLease {
        MemLease { budget, charged: 0 }
    }

    /// Bytes currently charged by this lease.
    pub fn charged(&self) -> u64 {
        self.charged
    }

    /// Adjust the charge to `bytes` (delta against the shared gauge).
    pub fn set(&mut self, bytes: u64) {
        if bytes > self.charged {
            self.budget.charge(bytes - self.charged);
        } else {
            self.budget.release(self.charged - bytes);
        }
        self.charged = bytes;
    }
}

impl Drop for MemLease {
    fn drop(&mut self) {
        self.budget.release(self.charged);
    }
}

/// Shared spill counters (one set per execution; clones share).
#[derive(Clone, Debug, Default)]
pub struct SpillCounters {
    bytes_spilled: Arc<AtomicU64>,
    bytes_read_back: Arc<AtomicU64>,
    partitions_spilled: Arc<AtomicU64>,
    files_created: Arc<AtomicU64>,
    max_recursion_depth: Arc<AtomicU64>,
    write_ns: Arc<AtomicU64>,
    read_ns: Arc<AtomicU64>,
}

impl SpillCounters {
    pub fn add_spilled(&self, bytes: u64) {
        self.bytes_spilled.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_read_back(&self, bytes: u64) {
        self.bytes_read_back.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_partition(&self) {
        self.partitions_spilled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_depth(&self, depth: u32) {
        self.max_recursion_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Snapshot into the plain [`SpillStats`] carried by
    /// `ExecSummary`.
    pub fn snapshot(&self, budget: &MemoryBudget) -> SpillStats {
        SpillStats {
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            bytes_read_back: self.bytes_read_back.load(Ordering::Relaxed),
            partitions_spilled: self.partitions_spilled.load(Ordering::Relaxed),
            spill_files_created: self.files_created.load(Ordering::Relaxed),
            max_recursion_depth: self.max_recursion_depth.load(Ordering::Relaxed),
            budget_limit: budget.limit(),
            budget_high_water: budget.high_water(),
            spill_write_ns: self.write_ns.load(Ordering::Relaxed),
            spill_read_ns: self.read_ns.load(Ordering::Relaxed),
        }
    }
}

// Process-wide uniquifier for spill directory names (several
// executions can be live at once in one process — the service, the
// test harness).
static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// The per-execution spill directory: created lazily under the
/// configured base (or the system temp dir) on the first file
/// creation, removed recursively on drop. Everything an execution
/// spills — operator partitions, sort runs, `MatStore` chunks — lives
/// here, so cleanup is one `remove_dir_all` no matter which teardown
/// path (finish, cancel, abort, panic) ran.
#[derive(Debug)]
pub struct SpillDir {
    base: PathBuf,
    created: Mutex<Option<PathBuf>>,
    file_seq: AtomicU64,
}

impl SpillDir {
    fn new(base: PathBuf) -> SpillDir {
        SpillDir { base, created: Mutex::new(None), file_seq: AtomicU64::new(0) }
    }

    /// The directory path, creating it on first use.
    pub fn ensure(&self) -> PathBuf {
        let mut guard = self.created.lock().unwrap();
        if let Some(p) = guard.as_ref() {
            return p.clone();
        }
        let name = format!(
            "ooc-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = self.base.join(name);
        std::fs::create_dir_all(&path).expect("create spill directory");
        *guard = Some(path.clone());
        path
    }

    /// The directory path if any file was ever spilled.
    pub fn path(&self) -> Option<PathBuf> {
        self.created.lock().unwrap().clone()
    }

    fn next_file(&self) -> u64 {
        self.file_seq.fetch_add(1, Ordering::Relaxed)
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        if let Some(p) = self.created.lock().unwrap().take() {
            let _ = std::fs::remove_dir_all(&p);
        }
    }
}

/// Everything an operator needs to participate in out-of-core
/// execution: the shared budget, the shared counters and the
/// execution's spill directory. One per execution, cloned into every
/// worker's context; the last clone's drop removes the directory.
#[derive(Clone, Debug, Default)]
pub struct SpillCtx {
    pub budget: MemoryBudget,
    pub counters: SpillCounters,
    dir: Arc<SpillDir>,
}

impl Default for SpillDir {
    fn default() -> SpillDir {
        SpillDir::new(std::env::temp_dir())
    }
}

impl SpillCtx {
    pub fn new(config: &Config) -> SpillCtx {
        let base = if config.spill_dir.is_empty() {
            std::env::temp_dir()
        } else {
            PathBuf::from(&config.spill_dir)
        };
        SpillCtx {
            budget: MemoryBudget::new(config.memory_budget_bytes),
            counters: SpillCounters::default(),
            dir: Arc::new(SpillDir::new(base)),
        }
    }

    /// The execution's spill directory path, if anything was spilled.
    pub fn dir_path(&self) -> Option<PathBuf> {
        self.dir.path()
    }
}

/// One spill file's manifest entry: enough to reopen it byte-exactly.
/// Travels inside `OpState::spill`, so checkpoints embed the manifest
/// and recovery replays it (`set_len(bytes)` truncates any appends
/// that post-date the checkpoint).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpillSlot {
    /// Operator-defined stream kind (e.g. join build vs probe).
    pub tag: u32,
    /// Operator-defined scope (partition id, sort scope, …).
    pub scope: u64,
    /// Operator-defined sequence within (tag, scope) — sort run order.
    pub seq: u64,
    /// Absolute file path inside the execution's [`SpillDir`].
    pub path: String,
    /// Valid byte length (appends past a checkpoint are truncated on
    /// restore).
    pub bytes: u64,
    /// Row count at `bytes`.
    pub rows: u64,
}

/// An open, append-only spill file. Frames are flushed at the end of
/// every [`SpillFile::append`] so an immutable snapshot
/// ([`SpillFile::slot`]) is always byte-accurate. Files are never
/// deleted mid-run — see the module docs.
#[derive(Debug)]
pub struct SpillFile {
    file: File,
    slot: SpillSlot,
    counters: SpillCounters,
}

impl SpillFile {
    /// Create a fresh file in the execution's spill directory.
    pub fn create(ctx: &SpillCtx, tag: u32, scope: u64, seq: u64) -> SpillFile {
        let dir = ctx.dir.ensure();
        let path = dir.join(format!("f{}.spill", ctx.dir.next_file()));
        let file = File::create(&path).expect("create spill file");
        ctx.counters.files_created.fetch_add(1, Ordering::Relaxed);
        SpillFile {
            file,
            slot: SpillSlot {
                tag,
                scope,
                seq,
                path: path.to_string_lossy().into_owned(),
                bytes: 0,
                rows: 0,
            },
            counters: ctx.counters.clone(),
        }
    }

    /// Reopen a checkpointed file for further appends, truncating any
    /// bytes past the manifest's recorded length (appends that
    /// post-dated the checkpoint).
    pub fn reopen(ctx: &SpillCtx, slot: &SpillSlot) -> SpillFile {
        let file = OpenOptions::new()
            .write(true)
            .open(&slot.path)
            .expect("reopen spill file");
        file.set_len(slot.bytes).expect("truncate spill file");
        let mut f = SpillFile { file, slot: slot.clone(), counters: ctx.counters.clone() };
        f.file
            .seek(SeekFrom::Start(slot.bytes))
            .expect("seek spill file");
        f
    }

    /// The manifest entry describing the file's current contents.
    pub fn slot(&self) -> SpillSlot {
        self.slot.clone()
    }

    /// Rows written so far.
    pub fn rows(&self) -> u64 {
        self.slot.rows
    }

    /// Bytes written so far.
    pub fn bytes(&self) -> u64 {
        self.slot.bytes
    }

    /// Append one frame of tuples (no-op on an empty slice) and flush,
    /// so the slot returned by [`SpillFile::slot`] is immediately
    /// durable for checkpoint manifests.
    pub fn append(&mut self, rows: &[Tuple]) {
        if rows.is_empty() {
            return;
        }
        let started = std::time::Instant::now();
        let payload = encode_frame(rows);
        let mut buf = Vec::with_capacity(8 + payload.len());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&payload);
        self.file.write_all(&buf).expect("write spill frame");
        self.file.flush().expect("flush spill file");
        self.slot.bytes += buf.len() as u64;
        self.slot.rows += rows.len() as u64;
        self.counters.add_spilled(buf.len() as u64);
        // Encode+write time feeds the cost model's calibrated spill
        // bandwidth (`CostParams::calibrate_spill`).
        self.counters
            .write_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Streaming frame reader over a spill file's valid prefix
/// (`[0, limit)` bytes). Yields columnar [`TupleBatch`]es — one per
/// written frame — without transposition when the frame was stored
/// columnar.
#[derive(Debug)]
pub struct SpillReader {
    reader: BufReader<File>,
    remaining: u64,
    counters: SpillCounters,
}

impl SpillReader {
    /// Open `slot.path` for reading its first `slot.bytes` bytes.
    pub fn open(ctx: &SpillCtx, slot: &SpillSlot) -> SpillReader {
        let file = File::open(&slot.path).expect("open spill file");
        SpillReader {
            reader: BufReader::new(file),
            remaining: slot.bytes,
            counters: ctx.counters.clone(),
        }
    }

    /// The next frame as a batch, or `None` at the valid-prefix end.
    pub fn next_batch(&mut self) -> Option<TupleBatch> {
        if self.remaining < 8 {
            return None;
        }
        let started = std::time::Instant::now();
        let mut len8 = [0u8; 8];
        self.reader.read_exact(&mut len8).expect("read spill frame length");
        let len = u64::from_le_bytes(len8);
        assert!(
            self.remaining >= 8 + len,
            "spill frame extends past valid prefix"
        );
        let mut payload = vec![0u8; len as usize];
        self.reader.read_exact(&mut payload).expect("read spill frame");
        self.remaining -= 8 + len;
        self.counters.add_read_back(8 + len);
        let batch = decode_frame(&payload);
        self.counters
            .read_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Some(batch)
    }

    /// The next frame as rows (materializing columnar frames).
    pub fn next_rows(&mut self) -> Option<Vec<Tuple>> {
        self.next_batch().map(|b| b.as_slice().to_vec())
    }
}

/// Read a whole slot back as rows (state restore / unspill paths).
pub fn read_slot_rows(ctx: &SpillCtx, slot: &SpillSlot) -> Vec<Tuple> {
    let mut reader = SpillReader::open(ctx, slot);
    let mut out = Vec::with_capacity(slot.rows as usize);
    while let Some(rows) = reader.next_rows() {
        out.extend(rows);
    }
    out
}

// ---------------------------------------------------------------------------
// Frame encoding. A frame is self-describing:
//
//   payload := [u8 kind]
//              kind 0 (columnar): [u64 nrows][u32 arity] column*
//                column := [u8 coltag][u8 has_validity] values
//                                     [validity: nrows bytes]?
//                  coltag 0 Int:   nrows × i64 LE
//                  coltag 1 Float: nrows × f64 bits LE (bit-preserving)
//                  coltag 2 Str:   nrows × ([u32 len] bytes)
//                  coltag 3 Mixed: nrows × value
//              kind 1 (rows, ragged/zero-arity fallback):
//                [u64 nrows] nrows × ([u32 arity] arity × value)
//   value   := [u8 vtag] (0 Null | 1 Int i64 | 2 Float bits | 3 Str)
//
// Floats round-trip by bit pattern (NaN payloads, signed zeros), so
// recovery replay is byte-exact.
// ---------------------------------------------------------------------------

fn put_value(buf: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Null => buf.push(0),
        Value::Int(i) => {
            buf.push(1);
            buf.extend_from_slice(&i.to_le_bytes());
        }
        Value::Float(f) => {
            buf.push(2);
            buf.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        Value::Str(s) => {
            buf.push(3);
            buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
            buf.extend_from_slice(s.as_bytes());
        }
    }
}

fn encode_frame(rows: &[Tuple]) -> Vec<u8> {
    let mut buf = Vec::new();
    let columnar = ColumnSet::from_rows(rows).filter(|s| s.arity() > 0);
    match columnar {
        Some(set) => {
            buf.push(0u8);
            buf.extend_from_slice(&(set.len() as u64).to_le_bytes());
            buf.extend_from_slice(&(set.arity() as u32).to_le_bytes());
            for col in &set.cols {
                encode_column(&mut buf, col, set.len());
            }
        }
        None => {
            buf.push(1u8);
            buf.extend_from_slice(&(rows.len() as u64).to_le_bytes());
            for t in rows {
                buf.extend_from_slice(&(t.arity() as u32).to_le_bytes());
                for v in &t.values {
                    put_value(&mut buf, v);
                }
            }
        }
    }
    buf
}

fn encode_validity(buf: &mut Vec<u8>, validity: &Option<Vec<bool>>) {
    if let Some(m) = validity {
        buf.push(1);
        buf.extend(m.iter().map(|&b| b as u8));
    } else {
        buf.push(0);
    }
}

fn encode_column(buf: &mut Vec<u8>, col: &Column, _nrows: usize) {
    match col {
        Column::Int { vals, validity } => {
            buf.push(0);
            encode_validity(buf, validity);
            for v in vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Column::Float { vals, validity } => {
            buf.push(1);
            encode_validity(buf, validity);
            for v in vals {
                buf.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        Column::Str { vals, validity } => {
            buf.push(2);
            encode_validity(buf, validity);
            for s in vals {
                buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                buf.extend_from_slice(s.as_bytes());
            }
        }
        Column::Mixed { vals } => {
            buf.push(3);
            buf.push(0);
            for v in vals {
                put_value(buf, v);
            }
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    fn u8(&mut self) -> u8 {
        let b = self.buf[self.pos];
        self.pos += 1;
        b
    }

    fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    fn i64(&mut self) -> i64 {
        i64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    fn str_arc(&mut self) -> Arc<str> {
        let len = self.u32() as usize;
        let bytes = self.take(len);
        Arc::from(std::str::from_utf8(bytes).expect("utf8 spill string"))
    }

    fn value(&mut self) -> Value {
        match self.u8() {
            0 => Value::Null,
            1 => Value::Int(self.i64()),
            2 => Value::Float(f64::from_bits(self.u64())),
            3 => Value::Str(self.str_arc()),
            t => panic!("corrupt spill frame: value tag {t}"),
        }
    }

    fn validity(&mut self, nrows: usize) -> Option<Vec<bool>> {
        if self.u8() == 1 {
            Some(self.take(nrows).iter().map(|&b| b != 0).collect())
        } else {
            None
        }
    }
}

fn decode_frame(payload: &[u8]) -> TupleBatch {
    let mut d = Dec { buf: payload, pos: 0 };
    match d.u8() {
        0 => {
            let nrows = d.u64() as usize;
            let arity = d.u32() as usize;
            let mut cols = Vec::with_capacity(arity);
            for _ in 0..arity {
                let coltag = d.u8();
                match coltag {
                    0 => {
                        let validity = d.validity(nrows);
                        let vals = (0..nrows).map(|_| d.i64()).collect();
                        cols.push(Column::Int { vals, validity });
                    }
                    1 => {
                        let validity = d.validity(nrows);
                        let vals =
                            (0..nrows).map(|_| f64::from_bits(d.u64())).collect();
                        cols.push(Column::Float { vals, validity });
                    }
                    2 => {
                        let validity = d.validity(nrows);
                        let vals = (0..nrows).map(|_| d.str_arc()).collect();
                        cols.push(Column::Str { vals, validity });
                    }
                    3 => {
                        d.u8(); // validity flag, always 0 for Mixed
                        let vals = (0..nrows).map(|_| d.value()).collect();
                        cols.push(Column::Mixed { vals });
                    }
                    t => panic!("corrupt spill frame: column tag {t}"),
                }
            }
            TupleBatch::from_columns(ColumnSet::new(cols, nrows))
        }
        1 => {
            let nrows = d.u64() as usize;
            let rows = (0..nrows)
                .map(|_| {
                    let arity = d.u32() as usize;
                    Tuple::new((0..arity).map(|_| d.value()).collect())
                })
                .collect();
            TupleBatch::new(rows)
        }
        k => panic!("corrupt spill frame: kind {k}"),
    }
}

/// Sum of [`Tuple::byte_size`] over a row slice — the resident-state
/// accounting unit shared by every spilling operator.
pub fn rows_byte_size(rows: &[Tuple]) -> u64 {
    rows.iter().map(|t| t.byte_size() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn test_ctx(limit: u64) -> SpillCtx {
        let mut cfg = Config::for_tests();
        cfg.memory_budget_bytes = limit;
        SpillCtx::new(&cfg)
    }

    fn sample_rows() -> Vec<Tuple> {
        vec![
            Tuple::new(vec![Value::Int(7), Value::Float(2.5), Value::str("abc")]),
            Tuple::new(vec![Value::Null, Value::Float(-0.0), Value::str("")]),
            Tuple::new(vec![Value::Int(-3), Value::Null, Value::str("abcdefgh")]),
            Tuple::new(vec![
                Value::Int(0),
                Value::Float(f64::from_bits(0x7ff8_0000_0000_1234)), // NaN payload
                Value::Null,
            ]),
        ]
    }

    #[test]
    fn frame_roundtrip_columnar_bit_exact() {
        let rows = sample_rows();
        let batch = decode_frame(&encode_frame(&rows));
        assert!(batch.has_columns(), "uniform-arity frame stays columnar");
        assert_eq!(batch.len(), rows.len());
        for (i, want) in rows.iter().enumerate() {
            let got = batch.get(i);
            assert_eq!(got.arity(), want.arity());
            for c in 0..want.arity() {
                match (got.get(c), want.get(c)) {
                    (Value::Float(a), Value::Float(b)) => {
                        assert_eq!(a.to_bits(), b.to_bits(), "row {i} col {c}");
                    }
                    (a, b) => assert_eq!(a, b, "row {i} col {c}"),
                }
            }
        }
    }

    #[test]
    fn frame_roundtrip_ragged_rows() {
        let rows = vec![
            Tuple::new(vec![Value::Int(1)]),
            Tuple::new(vec![Value::Int(2), Value::str("xy")]),
            Tuple::new(vec![]),
        ];
        let batch = decode_frame(&encode_frame(&rows));
        assert!(!batch.has_columns());
        assert_eq!(batch.as_slice(), &rows[..]);
    }

    #[test]
    fn file_roundtrip_and_dir_cleanup() {
        let ctx = test_ctx(0);
        let rows = sample_rows();
        let mut f = SpillFile::create(&ctx, 3, 42, 0);
        f.append(&rows[..2]);
        f.append(&rows[2..]);
        f.append(&[]); // no-op
        let slot = f.slot();
        assert_eq!(slot.tag, 3);
        assert_eq!(slot.scope, 42);
        assert_eq!(slot.rows, 4);
        let dir = ctx.dir_path().expect("dir created");
        assert!(dir.is_dir());
        assert!(Path::new(&slot.path).is_file());

        let got = read_slot_rows(&ctx, &slot);
        assert_eq!(got.len(), rows.len());
        assert_eq!(format!("{got:?}"), format!("{rows:?}"));

        let stats = ctx.counters.snapshot(&ctx.budget);
        assert!(stats.bytes_spilled > 0);
        assert_eq!(stats.bytes_read_back, stats.bytes_spilled);
        assert_eq!(stats.spill_files_created, 1);

        drop(f);
        drop(ctx);
        assert!(!dir.exists(), "spill dir removed on ctx drop");
    }

    #[test]
    fn reopen_truncates_past_manifest() {
        let ctx = test_ctx(0);
        let rows = sample_rows();
        let mut f = SpillFile::create(&ctx, 0, 0, 0);
        f.append(&rows[..2]);
        let checkpointed = f.slot();
        f.append(&rows[2..]); // post-checkpoint appends...
        drop(f);
        // ...must vanish on restore.
        let mut re = SpillFile::reopen(&ctx, &checkpointed);
        assert_eq!(re.bytes(), checkpointed.bytes);
        let got = read_slot_rows(&ctx, &re.slot());
        assert_eq!(format!("{got:?}"), format!("{:?}", &rows[..2]));
        // And appends continue from the truncation point.
        re.append(&rows[2..3]);
        let got = read_slot_rows(&ctx, &re.slot());
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn budget_lease_accounting() {
        let budget = MemoryBudget::new(100);
        let mut a = MemLease::new(budget.clone());
        let mut b = MemLease::new(budget.clone());
        a.set(60);
        assert!(!budget.over());
        b.set(50);
        assert!(budget.over());
        assert_eq!(budget.used(), 110);
        assert_eq!(budget.high_water(), 110);
        a.set(10);
        assert!(!budget.over());
        assert_eq!(budget.used(), 60);
        drop(b);
        assert_eq!(budget.used(), 10);
        drop(a);
        assert_eq!(budget.used(), 0);
        assert_eq!(budget.high_water(), 110, "high water survives releases");
    }

    #[test]
    fn unbounded_budget_never_over_but_tracks() {
        let budget = MemoryBudget::new(0);
        let mut l = MemLease::new(budget.clone());
        l.set(1 << 40);
        assert!(!budget.over());
        assert_eq!(budget.high_water(), 1 << 40);
    }

    #[test]
    fn partition_bits_above_routing_bits() {
        let h = 0xABCD_EF01_2345_6789u64;
        assert_eq!(partition_of(h, 0), ((h >> 8) & 15) as usize);
        assert_eq!(partition_of(h, 1), ((h >> 12) & 15) as usize);
        // Depths use disjoint nibbles: flipping routing bits (low 8)
        // never changes any partition.
        let h2 = h ^ 0xFF;
        for d in 0..SPILL_MAX_DEPTH {
            assert_eq!(partition_of(h, d), partition_of(h2, d));
        }
    }
}

//! The physical-operator interface and its runtime context.
//!
//! Operators follow the paper's *iteration model* (§2.4.3): the worker
//! loop feeds tuples one at a time into [`Operator::process`], which
//! emits zero or more output tuples through the [`Emitter`]. Because
//! control is checked *between* iterations, any operator written against
//! this trait automatically supports sub-second pause, conditional
//! breakpoints and runtime modification.
//!
//! State management: operators expose their keyed state ([`OpState`],
//! §3.5.1) for (a) quiesced checkpointing (§2.6.2) and (b) Reshape's
//! state migration — extraction of a key subset for SBK, or full
//! replication for SBR on immutable-state phases.

use crate::tuple::Tuple;
use std::collections::HashMap;

/// Serializable operator state: the "keyed state" of §3.5.1, a mapping
/// `scope → val`. Scopes are stable key hashes; values are tuple lists
/// (hash tables, sorted runs) or aggregates.
#[derive(Clone, Debug, Default)]
pub struct OpState {
    /// Keyed tuple lists (e.g. build-side rows per join key, sorted run
    /// per range).
    pub keyed_tuples: HashMap<u64, Vec<Tuple>>,
    /// Keyed scalar aggregates (e.g. running group-by sums/counts).
    pub keyed_aggs: HashMap<u64, Vec<f64>>,
    /// Opaque counters (operator-specific).
    pub counters: HashMap<String, i64>,
}

impl OpState {
    pub fn is_empty(&self) -> bool {
        self.keyed_tuples.is_empty() && self.keyed_aggs.is_empty() && self.counters.is_empty()
    }

    /// Approximate size in tuples (for state-migration-time modeling).
    pub fn size_tuples(&self) -> usize {
        self.keyed_tuples.values().map(Vec::len).sum::<usize>() + self.keyed_aggs.len()
    }

    /// Merge another state into this one (helper receiving migrated
    /// state; scattered-state merge for sort is operator-specific and
    /// overrides this).
    pub fn merge(&mut self, other: OpState) {
        for (k, mut v) in other.keyed_tuples {
            self.keyed_tuples.entry(k).or_default().append(&mut v);
        }
        for (k, v) in other.keyed_aggs {
            let e = self.keyed_aggs.entry(k).or_insert_with(|| vec![0.0; v.len()]);
            for (a, b) in e.iter_mut().zip(v) {
                *a += b;
            }
        }
        for (k, v) in other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
    }
}

/// A runtime patch to an operator's parameters (§2.4.4: "change the
/// logic of an operator, e.g., by modifying the keywords in
/// KeywordSearch" / "the constant in a selection predicate").
#[derive(Clone, Debug)]
pub struct OpPatch {
    /// Parameter name understood by the operator.
    pub param: String,
    /// New value, operator-parsed.
    pub value: String,
}

/// Output collector handed to operators. The worker implements this and
/// routes emitted tuples through its per-edge partitioners, evaluates
/// local breakpoints, and maintains produced-counters for global
/// breakpoints.
pub trait Emitter {
    /// Emit one output tuple.
    fn emit(&mut self, t: Tuple);
}

/// A simple vector-backed emitter for unit tests.
#[derive(Default)]
pub struct VecEmitter(pub Vec<Tuple>);

impl Emitter for VecEmitter {
    fn emit(&mut self, t: Tuple) {
        self.0.push(t);
    }
}

/// A physical operator instance, owned by one worker.
pub trait Operator: Send {
    /// A short name for logs/stats.
    fn name(&self) -> &str;

    /// Process one input tuple from `port`.
    fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter);

    /// All upstream senders on `port` reached EOF. Blocking operators
    /// (sort, group-by second layer, hash-join build) act here.
    fn finish_port(&mut self, _port: usize, _out: &mut dyn Emitter) {}

    /// All input ports reached EOF; flush any remaining output.
    fn finish(&mut self, _out: &mut dyn Emitter) {}

    /// Number of input ports.
    fn num_ports(&self) -> usize {
        1
    }

    /// Which ports are *blocking* (§4.2: no output until the port's
    /// entire input is processed). Maestro reads this off the operator.
    fn blocking_ports(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Snapshot the full operator state (checkpointing).
    fn snapshot(&self) -> OpState {
        OpState::default()
    }

    /// Cheap state-size estimate in tuples (stats without cloning).
    fn state_size(&self) -> usize {
        0
    }

    /// Restore from a snapshot (recovery).
    fn restore(&mut self, _s: OpState) {}

    /// Extract state for the given key hashes (SBK migration) or all
    /// keys (`None`; SBR replication). If `replicate` the state is
    /// copied, not removed — immutable-state operators replicate
    /// (Fig. 3.10 branch (a)); mutable-state operators move.
    fn extract_state(&mut self, _keys: Option<&[u64]>, _replicate: bool) -> OpState {
        OpState::default()
    }

    /// Merge migrated state received from a skewed worker.
    fn merge_state(&mut self, _s: OpState) {}

    /// Whether this operator's *current phase* has mutable state
    /// (Table 3.1). The engine consults this to decide the migration
    /// protocol.
    fn state_mutable(&self) -> bool {
        false
    }

    /// Scattered-state parts held for *other* workers (§3.5.4): pairs
    /// of (owner worker index, state). Called at EOF when the operator
    /// runs under SBR mitigation; the engine ships each part to its
    /// owner before `finish` (the Fig. 3.11(e) END-marker merge).
    fn scattered_parts(&mut self) -> Vec<(u64, OpState)> {
        Vec::new()
    }

    /// Apply a runtime parameter patch; `Err` if unknown.
    fn modify(&mut self, patch: &OpPatch) -> Result<(), String> {
        Err(format!("{}: unknown parameter {}", self.name(), patch.param))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Value;

    #[test]
    fn opstate_merge_appends_tuples() {
        let mut a = OpState::default();
        a.keyed_tuples
            .insert(1, vec![Tuple::new(vec![Value::Int(1)])]);
        let mut b = OpState::default();
        b.keyed_tuples
            .insert(1, vec![Tuple::new(vec![Value::Int(2)])]);
        b.keyed_tuples
            .insert(2, vec![Tuple::new(vec![Value::Int(3)])]);
        a.merge(b);
        assert_eq!(a.keyed_tuples[&1].len(), 2);
        assert_eq!(a.keyed_tuples[&2].len(), 1);
        assert_eq!(a.size_tuples(), 3);
    }

    #[test]
    fn opstate_merge_sums_aggs() {
        let mut a = OpState::default();
        a.keyed_aggs.insert(7, vec![10.0, 2.0]);
        let mut b = OpState::default();
        b.keyed_aggs.insert(7, vec![5.0, 1.0]);
        a.merge(b);
        assert_eq!(a.keyed_aggs[&7], vec![15.0, 3.0]);
    }

    #[test]
    fn opstate_merge_counters() {
        let mut a = OpState::default();
        a.counters.insert("n".into(), 3);
        let mut b = OpState::default();
        b.counters.insert("n".into(), 4);
        a.merge(b);
        assert_eq!(a.counters["n"], 7);
    }
}

//! The physical-operator interface and its runtime context.
//!
//! Operators follow a *batched* version of the paper's iteration model
//! (§2.4.3): the worker feeds [`TupleBatch`] chunks into
//! [`Operator::process_batch`], which emits output through the
//! [`Emitter`]. The default `process_batch` loops over
//! [`Operator::process`] one tuple at a time, so tuple-at-a-time
//! operators keep working unchanged; hot operators override the batch
//! hook to amortize virtual dispatch and allocation across the chunk.
//!
//! Control semantics are preserved because the *worker* bounds chunk
//! length at `ctrl_check_interval` and re-checks the control flag
//! between chunks — the paper's per-iteration `Paused` check at a
//! configurable granularity (interval 1 reproduces §2.4.3 exactly).
//! Any operator written against this trait therefore still supports
//! sub-second pause, conditional breakpoints and runtime modification.
//!
//! State management: operators expose their keyed state ([`OpState`],
//! §3.5.1) for (a) quiesced checkpointing (§2.6.2) and (b) Reshape's
//! state migration — extraction of a key subset for SBK, or full
//! replication for SBR on immutable-state phases.

use crate::engine::spill::{SpillCtx, SpillSlot};
use crate::tuple::{Tuple, TupleBatch};
use std::collections::HashMap;

/// Serializable operator state: the "keyed state" of §3.5.1, a mapping
/// `scope → val`. Scopes are stable key hashes; values are tuple lists
/// (hash tables, sorted runs) or aggregates.
#[derive(Clone, Debug, Default)]
pub struct OpState {
    /// Keyed tuple lists (e.g. build-side rows per join key, sorted run
    /// per range).
    pub keyed_tuples: HashMap<u64, Vec<Tuple>>,
    /// Keyed scalar aggregates (e.g. running group-by sums/counts).
    pub keyed_aggs: HashMap<u64, Vec<f64>>,
    /// Opaque counters (operator-specific).
    pub counters: HashMap<String, i64>,
    /// Spill-file manifest for out-of-core state
    /// ([`crate::engine::spill`]): checkpoints carry the slots instead
    /// of the spilled bytes, and recovery reopens the files byte-exactly.
    /// Migration/scale extraction paths surrender *unspilled* state
    /// (operators read partitions back before extracting), so this is
    /// populated only by [`Operator::snapshot`].
    pub spill: Vec<SpillSlot>,
}

impl OpState {
    pub fn is_empty(&self) -> bool {
        self.keyed_tuples.is_empty()
            && self.keyed_aggs.is_empty()
            && self.counters.is_empty()
            && self.spill.is_empty()
    }

    /// Approximate size in tuples (for state-migration-time modeling).
    pub fn size_tuples(&self) -> usize {
        self.keyed_tuples.values().map(Vec::len).sum::<usize>() + self.keyed_aggs.len()
    }

    /// Partition this state across `n` owners by stable key hash
    /// (`scope → scope % n`), the inverse of how hash partitioning
    /// routes tuples. Used by elastic scaling to redistribute the
    /// combined state of the old worker set over the new one; entries
    /// for the same scope (tuples + aggregates) stay together. Unkeyed
    /// `counters` land on owner 0.
    pub fn split_by_hash(self, n: usize) -> Vec<OpState> {
        assert!(n > 0);
        let mut shards: Vec<OpState> = (0..n).map(|_| OpState::default()).collect();
        for (k, v) in self.keyed_tuples {
            shards[(k % n as u64) as usize].keyed_tuples.insert(k, v);
        }
        for (k, v) in self.keyed_aggs {
            shards[(k % n as u64) as usize].keyed_aggs.insert(k, v);
        }
        for (k, v) in self.counters {
            shards[0].counters.insert(k, v);
        }
        // Spill manifests are not key-addressable from the outside;
        // extraction paths unspill before extracting, so slots here can
        // only come from a snapshot — keep them with the counters.
        shards[0].spill = self.spill;
        shards
    }

    /// Merge another state into this one (helper receiving migrated
    /// state; scattered-state merge for sort is operator-specific and
    /// overrides this).
    pub fn merge(&mut self, other: OpState) {
        for (k, mut v) in other.keyed_tuples {
            self.keyed_tuples.entry(k).or_default().append(&mut v);
        }
        for (k, v) in other.keyed_aggs {
            let e = self.keyed_aggs.entry(k).or_insert_with(|| vec![0.0; v.len()]);
            for (a, b) in e.iter_mut().zip(v) {
                *a += b;
            }
        }
        for (k, v) in other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        self.spill.extend(other.spill);
    }
}

/// A runtime patch to an operator's parameters (§2.4.4: "change the
/// logic of an operator, e.g., by modifying the keywords in
/// KeywordSearch" / "the constant in a selection predicate").
#[derive(Clone, Debug)]
pub struct OpPatch {
    /// Parameter name understood by the operator.
    pub param: String,
    /// New value, operator-parsed.
    pub value: String,
}

/// Output collector handed to operators. The worker implements this and
/// routes emitted tuples through its per-edge partitioners, evaluates
/// local breakpoints, and maintains produced-counters for global
/// breakpoints.
pub trait Emitter {
    /// Emit one output tuple.
    fn emit(&mut self, t: Tuple);

    /// Emit a whole batch. The default forwards tuple by tuple; the
    /// worker's output stage overrides it to scatter the batch through
    /// the partitioner in one pass and to forward the *shared*
    /// allocation on fan-out edges (zero per-destination clones).
    fn emit_batch(&mut self, batch: TupleBatch) {
        for t in batch.iter() {
            self.emit(t.clone());
        }
    }
}

/// A simple vector-backed emitter for unit tests.
#[derive(Default)]
pub struct VecEmitter(pub Vec<Tuple>);

impl Emitter for VecEmitter {
    fn emit(&mut self, t: Tuple) {
        self.0.push(t);
    }
}

/// A physical operator instance, owned by one worker.
pub trait Operator: Send {
    /// A short name for logs/stats.
    fn name(&self) -> &str;

    /// Process one input tuple from `port`.
    fn process(&mut self, t: Tuple, port: usize, out: &mut dyn Emitter);

    /// Process a chunk of input tuples from `port`. This is the
    /// worker's default entry point; the chunk length is bounded by
    /// `ctrl_check_interval`, so overriding operators never hold the
    /// DP loop longer than one control-check window. The default
    /// implementation loops over [`Operator::process`] and must stay
    /// observationally identical to any override (same emitted
    /// multiset, same state transitions, in batch order).
    fn process_batch(&mut self, batch: &TupleBatch, port: usize, out: &mut dyn Emitter) {
        for t in batch.iter() {
            self.process(t.clone(), port, out);
        }
    }

    /// Process a chunk whose sender shipped its memoized key-hash
    /// column ([`crate::engine::message::HashColumn`]): `hashes[i]` is
    /// `batch.get(i).get(key).stable_hash()`, already computed by the
    /// upstream exchange. The default ignores the hashes and delegates
    /// to [`Operator::process_batch`]; keyed operators (hash-join
    /// probe, both group-by layers) override to skip re-hashing when
    /// `key` matches their own key field. Overrides must stay
    /// observationally identical to `process_batch` — the shipped
    /// hashes are byte-equal to locally computed ones by construction.
    fn process_batch_hashed(
        &mut self,
        batch: &TupleBatch,
        _key: usize,
        _hashes: &[u64],
        port: usize,
        out: &mut dyn Emitter,
    ) {
        self.process_batch(batch, port, out);
    }

    /// All upstream senders on `port` reached EOF. Blocking operators
    /// (sort, group-by second layer, hash-join build) act here.
    fn finish_port(&mut self, _port: usize, _out: &mut dyn Emitter) {}

    /// All input ports reached EOF; flush any remaining output.
    fn finish(&mut self, _out: &mut dyn Emitter) {}

    /// Number of input ports.
    fn num_ports(&self) -> usize {
        1
    }

    /// Which ports are *blocking* (§4.2: no output until the port's
    /// entire input is processed). Maestro reads this off the operator.
    fn blocking_ports(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Snapshot the full operator state (checkpointing).
    fn snapshot(&self) -> OpState {
        OpState::default()
    }

    /// Cheap state-size estimate in tuples (stats without cloning).
    fn state_size(&self) -> usize {
        0
    }

    /// Restore from a snapshot (recovery).
    fn restore(&mut self, _s: OpState) {}

    /// Extract state for the given key hashes (SBK migration) or all
    /// keys (`None`; SBR replication). If `replicate` the state is
    /// copied, not removed — immutable-state operators replicate
    /// (Fig. 3.10 branch (a)); mutable-state operators move.
    fn extract_state(&mut self, _keys: Option<&[u64]>, _replicate: bool) -> OpState {
        OpState::default()
    }

    /// Merge migrated state received from a skewed worker.
    fn merge_state(&mut self, _s: OpState) {}

    /// Install a re-hashed state shard during elastic scaling. The
    /// default delegates to [`Operator::merge_state`]; operators whose
    /// merge semantics differ between skew mitigation and scaling can
    /// override.
    fn install_state(&mut self, s: OpState) {
        self.merge_state(s);
    }

    /// The operator's parallelism changed at runtime (elastic scaling):
    /// this instance is now worker `idx` of `workers`. Operators that
    /// cache their (idx, n) placement — e.g. group-by's scattered-state
    /// ownership — update it here; the default is a no-op.
    fn rescale(&mut self, _idx: usize, _workers: usize) {}

    /// Whether this operator's *current phase* has mutable state
    /// (Table 3.1). The engine consults this to decide the migration
    /// protocol.
    fn state_mutable(&self) -> bool {
        false
    }

    /// Elastic scaling of a **broadcast-input** operator: return a copy
    /// of the state built from broadcast deliveries (the "build side"),
    /// installable on a scale-spawned worker via
    /// [`Operator::install_replica`]. Every worker of a broadcast-input
    /// operator holds an identical replica of this state, so one donor's
    /// copy plus its pending broadcast input reconstructs the stream a
    /// new worker missed (the Spark-AQE broadcast-build argument). The
    /// default returns the full [`Operator::snapshot`] — correct for
    /// operators whose whole state derives from broadcast input;
    /// operators that also hold per-worker state (e.g. a join's
    /// early-probe buffer) override to exclude it.
    fn replicate_broadcast_state(&self) -> OpState {
        self.snapshot()
    }

    /// Install a broadcast-side replica produced by
    /// [`Operator::replicate_broadcast_state`] on a freshly spawned
    /// worker. Defaults to [`Operator::restore`].
    fn install_replica(&mut self, s: OpState) {
        self.restore(s);
    }

    /// Elastic scale-down of a **broadcast-input** operator: surrender
    /// the keyed state derived from *partitioned* (non-broadcast) input
    /// only — the complement of
    /// [`Operator::replicate_broadcast_state`]. A retiring replica
    /// holder's broadcast-side state is redundant (every survivor keeps
    /// an identical copy), but its partitioned-port keyed state is
    /// unique and must be re-hashed onto the survivors. The default
    /// returns an empty state — correct for operators whose whole state
    /// derives from broadcast input; mixed-port operators with keyed
    /// non-broadcast state (e.g. [`crate::operators::Enrich`]'s per-key
    /// counts) override it. The operator must forget the returned
    /// state.
    fn partitioned_state(&mut self) -> OpState {
        OpState::default()
    }

    /// Surrender buffered *input* tuples that are neither reflected in
    /// emitted output nor in keyed state — e.g. a hash join's
    /// early-probe buffer — as `(port, tuples)` pairs. Elastic scaling
    /// re-routes these through the new partitioner exactly like
    /// in-flight channel input, so a retiring worker's buffered rows
    /// reach their new owners instead of dying with it. The operator
    /// must forget the returned tuples.
    fn drain_buffered_input(&mut self) -> Vec<(usize, Vec<Tuple>)> {
        Vec::new()
    }

    /// Scattered-state parts held for *other* workers (§3.5.4): pairs
    /// of (owner worker index, state). Called at EOF when the operator
    /// runs under SBR mitigation; the engine ships each part to its
    /// owner before `finish` (the Fig. 3.11(e) END-marker merge).
    fn scattered_parts(&mut self) -> Vec<(u64, OpState)> {
        Vec::new()
    }

    /// Attach the execution's out-of-core context
    /// ([`crate::engine::spill::SpillCtx`]: shared memory budget,
    /// counters and spill directory). Called by the worker once at
    /// construction, *before* any snapshot restore, so restored spill
    /// manifests can reopen their files. The default ignores it —
    /// stateless operators never spill.
    fn attach_spill(&mut self, _ctx: &SpillCtx) {}

    /// Apply a runtime parameter patch; `Err` if unknown.
    fn modify(&mut self, patch: &OpPatch) -> Result<(), String> {
        Err(format!("{}: unknown parameter {}", self.name(), patch.param))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Value;

    #[test]
    fn opstate_merge_appends_tuples() {
        let mut a = OpState::default();
        a.keyed_tuples
            .insert(1, vec![Tuple::new(vec![Value::Int(1)])]);
        let mut b = OpState::default();
        b.keyed_tuples
            .insert(1, vec![Tuple::new(vec![Value::Int(2)])]);
        b.keyed_tuples
            .insert(2, vec![Tuple::new(vec![Value::Int(3)])]);
        a.merge(b);
        assert_eq!(a.keyed_tuples[&1].len(), 2);
        assert_eq!(a.keyed_tuples[&2].len(), 1);
        assert_eq!(a.size_tuples(), 3);
    }

    #[test]
    fn opstate_merge_sums_aggs() {
        let mut a = OpState::default();
        a.keyed_aggs.insert(7, vec![10.0, 2.0]);
        let mut b = OpState::default();
        b.keyed_aggs.insert(7, vec![5.0, 1.0]);
        a.merge(b);
        assert_eq!(a.keyed_aggs[&7], vec![15.0, 3.0]);
    }

    #[test]
    fn default_process_batch_matches_per_tuple() {
        struct Doubler;
        impl Operator for Doubler {
            fn name(&self) -> &str {
                "doubler"
            }
            fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
                out.emit(t.clone());
                out.emit(t);
            }
        }
        let batch: TupleBatch =
            (0..5).map(|i| Tuple::new(vec![Value::Int(i)])).collect();
        let mut a = VecEmitter::default();
        Doubler.process_batch(&batch, 0, &mut a);
        let mut b = VecEmitter::default();
        for t in batch.iter() {
            Doubler.process(t.clone(), 0, &mut b);
        }
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn vec_emitter_emit_batch_appends_all() {
        let batch: TupleBatch =
            (0..4).map(|i| Tuple::new(vec![Value::Int(i)])).collect();
        let mut e = VecEmitter::default();
        e.emit_batch(batch.clone());
        assert_eq!(e.0.len(), 4);
        assert_eq!(e.0, batch.to_vec());
    }

    #[test]
    fn split_by_hash_partitions_and_preserves() {
        let mut s = OpState::default();
        for k in 0..10u64 {
            s.keyed_tuples.insert(k, vec![Tuple::new(vec![Value::Int(k as i64)])]);
            s.keyed_aggs.insert(k, vec![k as f64]);
        }
        s.counters.insert("c".into(), 5);
        let shards = s.split_by_hash(3);
        assert_eq!(shards.len(), 3);
        // Every key lands on exactly its hash owner; nothing lost.
        let mut seen = 0;
        for (i, sh) in shards.iter().enumerate() {
            for k in sh.keyed_tuples.keys() {
                assert_eq!((k % 3) as usize, i);
            }
            assert_eq!(sh.keyed_tuples.len(), sh.keyed_aggs.len());
            seen += sh.keyed_tuples.len();
        }
        assert_eq!(seen, 10);
        assert_eq!(shards[0].counters["c"], 5);
    }

    #[test]
    fn opstate_merge_counters() {
        let mut a = OpState::default();
        a.counters.insert("n".into(), 3);
        let mut b = OpState::default();
        b.counters.insert("n".into(), 4);
        a.merge(b);
        assert_eq!(a.counters["n"], 7);
    }
}

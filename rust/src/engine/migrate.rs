//! Live plan migration (Ch. 2/4 synthesis): the scale fence
//! generalized into a substrate for changing *plan structure* mid-run.
//!
//! Elastic scaling ([`crate::engine::scale`]) changes one fact about a
//! running plan — an operator's parallelism. The migration planner in
//! this module accepts a whole **plan delta** ([`PlanDelta`]):
//!
//! * **Repartition** — swap the partitioning scheme on a live edge,
//!   e.g. `Hash → Range` with bounds recomputed from the parked tuples
//!   themselves;
//! * **InsertMat / RemoveMat** — splice a materialization
//!   (writer/reader pair over a [`MatStore`]) onto a live edge, or
//!   undo one, without stopping the stream;
//! * **Replan** — a mid-region worker re-plan: a batch of parallelism
//!   changes emitted by Maestro's observation-driven re-planner.
//!
//! [`plan`] validates the delta against the current [`Workflow`] and
//! decomposes it into an ordered sequence of [`MigrationStep`]s; the
//! coordinator applies each step inside its own fence and reports a
//! [`StepOutcome`] trail in the [`MigrationOutcome`].
//!
//! [`MatStore`]: crate::maestro::materialize::MatStore
//!
//! # Protocol
//!
//! Every step reuses the scale-fence machinery (see the protocol
//! diagram in [`crate::engine::scale`]); what varies is the middle:
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!             │ for each MigrationStep, in order:              │
//!             │                                                │
//!  plan(Δ) ──▶│  1 FENCE    pause-all, await every ack         │
//!             │  2 UNPLUG   surrender state / parked input     │
//!             │             (step-specific worker set)         │
//!             │  3 MUTATE   the plan fact:                     │
//!             │              · scheme swap (Repartition)       │
//!             │              · splice writer+reader (InsertMat)│
//!             │              · un-splice + drain store (Remove)│
//!             │              · worker count (Replan → scale)   │
//!             │  4 REINJECT state to owners, parked input      │
//!             │             through the *new* routing          │
//!             │  5 REWIRE   partitioners, peers, EOF counts    │
//!             │  6 RESUME   (unless the driver holds a pause)  │
//!             └───────┬────────────────────────────────────────┘
//!                     │ fence refused / could not close
//!                     ▼
//!             ABORT-AND-RESTORE: surrendered state returns to its
//!             owners (`abort_scale`), then the already-applied step
//!             prefix is rolled back with inverse steps (RemoveMat
//!             undoes InsertMat, the old scheme undoes Repartition,
//!             the old count undoes Scale).
//! ```
//!
//! # Invariants, per step
//!
//! * **Routing totality** — after step 3 every in-flight and future
//!   tuple has exactly one destination under the new scheme set: parked
//!   input is re-routed through partitioners built from the *mutated*
//!   plan, and upstream edges are rebuilt (`RescaleEdge` /
//!   `RetargetEdge`) before the resume, so no tuple is ever routed by
//!   a mix of old and new schemes.
//! * **EOF accounting** — `UpdateUpstreamCount` rewrites the expected
//!   `End` count on every port whose live upstream worker set changed
//!   (mat insertion moves it to the reader's workers; removal moves it
//!   back), and surrendered `End` events are re-delivered to the same
//!   owner, so every port still sees exactly one `End` per live
//!   upstream worker.
//! * **Keyed-state colocation** — state shards live at
//!   `stable_hash(key) % n`. Repartitioning a *stateful* multi-worker
//!   operator would separate existing shards from future routing, so
//!   the fence aborts-and-restores instead (tested by the
//!   abort-restores-state regression). Worker re-plans re-shard
//!   through the scale fence's split/merge path as always.
//! * **Replay exactness** — a Repartition fence consolidates each
//!   worker's parked stream into one batch per port, renumbering the
//!   messages a control-replay record may reference. The unplug
//!   carries `preserve_routing: true`, the coordinator's promise that
//!   re-injection is routing-preserving (single-worker receiver set,
//!   one consolidated batch per port, port-ascending), under which the
//!   worker remaps parked replay positions exactly
//!   (`remap_replay_positions` in `engine/worker.rs` — the fence-aware
//!   replay remap).
//!
//! The Chameleon exemplar reconfigures a live network through planned
//! intermediate states, each of which must itself be valid; the same
//! discipline applies here — after every step (and after an abort) the
//! plan is a valid, running workflow.

use crate::engine::dag::Workflow;
use crate::engine::partitioner::PartitionScheme;
use crate::tuple::{value_cmp, Value};
use std::time::Duration;

/// A structural change to a *running* plan, applied through
/// [`crate::engine::Execution::migrate`].
#[derive(Clone, Debug)]
pub enum PlanDelta {
    /// Swap the partitioning scheme on input `port` of `op`. A `Range`
    /// scheme with empty bounds gets bounds recomputed from the tuples
    /// parked in the fence.
    Repartition { op: usize, port: usize, scheme: PartitionScheme },
    /// Materialize the live edge `from → (to, to_port)`: splice in a
    /// writer/reader pair around a shared store. The reader stays
    /// dormant until the writer's workers complete.
    InsertMat { from: usize, to: usize, to_port: usize },
    /// Undo a live materialization previously inserted on
    /// `from → (to, to_port)`: drain the store back into the restored
    /// direct edge. Refused once the writer has completed.
    RemoveMat { from: usize, to: usize, to_port: usize },
    /// Mid-region worker re-plan: set each listed operator's
    /// parallelism, in order (Maestro's observation-driven re-planner
    /// emits these).
    Replan { workers: Vec<(usize, usize)> },
}

/// One fenced step of a migration — the unit of apply and rollback.
#[derive(Clone, Debug)]
pub enum MigrationStep {
    Repartition { op: usize, port: usize, scheme: PartitionScheme },
    InsertMat { from: usize, to: usize, to_port: usize },
    RemoveMat { from: usize, to: usize, to_port: usize },
    Scale { op: usize, workers: usize },
}

impl MigrationStep {
    /// Human-readable step description for the outcome trail.
    pub fn describe(&self) -> String {
        match self {
            MigrationStep::Repartition { op, port, scheme } => {
                format!("repartition op {op} port {port} -> {scheme:?}")
            }
            MigrationStep::InsertMat { from, to, to_port } => {
                format!("insert mat on {from} -> ({to}, port {to_port})")
            }
            MigrationStep::RemoveMat { from, to, to_port } => {
                format!("remove mat on {from} -> ({to}, port {to_port})")
            }
            MigrationStep::Scale { op, workers } => {
                format!("scale op {op} -> {workers} workers")
            }
        }
    }
}

/// Outcome of one fenced step.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    pub desc: String,
    /// Fence duration; `Duration::ZERO` when refused or aborted.
    pub fence: Duration,
    pub applied: bool,
}

/// Outcome of a whole migration: the per-step trail plus whether the
/// delta as a whole applied, or aborted (and if so, whether a partial
/// prefix had to be rolled back).
#[derive(Clone, Debug, Default)]
pub struct MigrationOutcome {
    /// Every step applied; the plan now reflects the delta.
    pub applied: bool,
    /// An applied prefix was undone after a later step refused.
    pub rolled_back: bool,
    pub steps: Vec<StepOutcome>,
    pub total: Duration,
}

impl MigrationOutcome {
    /// Total fence time across applied steps (the paper's
    /// interruption-cost metric for a reconfiguration).
    pub fn fence_total(&self) -> Duration {
        self.steps.iter().map(|s| s.fence).sum()
    }
}

/// Validate `delta` against `w` and decompose it into an ordered
/// sequence of fenced steps. Static refusals only — conditions that
/// depend on runtime state (completed workers, a live-mat registry
/// entry, keyed-state colocation) are checked by the coordinator when
/// the step's fence opens.
pub fn plan(w: &Workflow, delta: &PlanDelta) -> Result<Vec<MigrationStep>, String> {
    match delta {
        PlanDelta::Repartition { op, port, scheme } => {
            let spec = w
                .ops
                .get(*op)
                .ok_or_else(|| format!("unknown operator {op}"))?;
            if *port >= spec.input_partitioning.len() {
                return Err(format!("operator {} has no input port {port}", spec.name));
            }
            if matches!(scheme, PartitionScheme::Broadcast)
                || matches!(
                    spec.input_partitioning[*port],
                    PartitionScheme::Broadcast
                )
            {
                return Err(
                    "broadcast topology changes are not a repartition (the \
                     replication protocol differs)"
                        .into(),
                );
            }
            Ok(vec![MigrationStep::Repartition {
                op: *op,
                port: *port,
                scheme: scheme.clone(),
            }])
        }
        PlanDelta::InsertMat { from, to, to_port } => {
            if !w
                .edges
                .iter()
                .any(|e| e.from == *from && e.to == *to && e.to_port == *to_port)
            {
                return Err(format!(
                    "no edge {from} -> ({to}, port {to_port}) in the plan"
                ));
            }
            Ok(vec![MigrationStep::InsertMat {
                from: *from,
                to: *to,
                to_port: *to_port,
            }])
        }
        PlanDelta::RemoveMat { from, to, to_port } => {
            if *from >= w.ops.len() || *to >= w.ops.len() {
                return Err(format!("unknown operator in {from} -> {to}"));
            }
            Ok(vec![MigrationStep::RemoveMat {
                from: *from,
                to: *to,
                to_port: *to_port,
            }])
        }
        PlanDelta::Replan { workers } => {
            if workers.is_empty() {
                return Err("empty re-plan".into());
            }
            for (op, n) in workers {
                if *op >= w.ops.len() {
                    return Err(format!("unknown operator {op}"));
                }
                if *n == 0 {
                    return Err(format!("operator {op}: zero workers"));
                }
            }
            Ok(workers
                .iter()
                .map(|(op, n)| MigrationStep::Scale { op: *op, workers: *n })
                .collect())
        }
    }
}

/// Range bounds for `parts` receivers from an observed value sample:
/// sorted-distinct quantile cuts (`parts - 1` upper bounds). Returns an
/// empty vector — routing everything to receiver 0, total but skewed —
/// when the sample has fewer distinct values than receivers; the
/// migration analogue of [`crate::engine::scale::rescale_bounds`],
/// which resizes *existing* bounds and so cannot invent them.
pub fn derive_bounds(mut sample: Vec<Value>, parts: usize) -> Vec<Value> {
    if parts <= 1 {
        return Vec::new();
    }
    sample.sort_by(value_cmp);
    sample.dedup();
    if sample.len() < parts {
        return Vec::new();
    }
    (1..parts)
        .map(|i| sample[i * sample.len() / parts].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dag::OpSpec;
    use crate::engine::operator::{Emitter, Operator};
    use crate::tuple::Tuple;
    use crate::workloads::VecSource;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn workflow() -> Workflow {
        let mut w = Workflow::new();
        let s = w.add(OpSpec::source("scan", 2, |_, _| {
            Box::new(VecSource::new(Vec::new()))
        }));
        let f = w.add(OpSpec::unary("filter", 2, PartitionScheme::RoundRobin, |_, _| {
            Box::new(Noop)
        }));
        w.connect(s, f, 0);
        w
    }

    #[test]
    fn repartition_plans_one_step() {
        let w = workflow();
        let steps = plan(
            &w,
            &PlanDelta::Repartition {
                op: 1,
                port: 0,
                scheme: PartitionScheme::Hash { key: 0 },
            },
        )
        .unwrap();
        assert_eq!(steps.len(), 1);
        assert!(matches!(
            steps[0],
            MigrationStep::Repartition { op: 1, port: 0, .. }
        ));
    }

    #[test]
    fn repartition_refuses_bad_targets() {
        let w = workflow();
        // Unknown op / port.
        assert!(plan(
            &w,
            &PlanDelta::Repartition { op: 9, port: 0, scheme: PartitionScheme::RoundRobin }
        )
        .is_err());
        assert!(plan(
            &w,
            &PlanDelta::Repartition { op: 1, port: 3, scheme: PartitionScheme::RoundRobin }
        )
        .is_err());
        // Broadcast in either direction.
        assert!(plan(
            &w,
            &PlanDelta::Repartition { op: 1, port: 0, scheme: PartitionScheme::Broadcast }
        )
        .is_err());
    }

    #[test]
    fn insert_mat_requires_the_edge() {
        let w = workflow();
        assert!(plan(&w, &PlanDelta::InsertMat { from: 0, to: 1, to_port: 0 }).is_ok());
        assert!(plan(&w, &PlanDelta::InsertMat { from: 1, to: 0, to_port: 0 }).is_err());
    }

    #[test]
    fn replan_decomposes_into_ordered_scales() {
        let w = workflow();
        let steps =
            plan(&w, &PlanDelta::Replan { workers: vec![(0, 3), (1, 4)] }).unwrap();
        assert_eq!(steps.len(), 2);
        assert!(matches!(steps[0], MigrationStep::Scale { op: 0, workers: 3 }));
        assert!(matches!(steps[1], MigrationStep::Scale { op: 1, workers: 4 }));
        assert!(plan(&w, &PlanDelta::Replan { workers: vec![] }).is_err());
        assert!(plan(&w, &PlanDelta::Replan { workers: vec![(1, 0)] }).is_err());
    }

    #[test]
    fn derive_bounds_quantile_cuts() {
        let sample: Vec<Value> = (0..100).map(Value::Int).collect();
        let b = derive_bounds(sample, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b, vec![Value::Int(25), Value::Int(50), Value::Int(75)]);
        // Too few distinct values: empty (degenerate but total).
        assert!(derive_bounds(vec![Value::Int(1), Value::Int(1)], 4).is_empty());
        assert!(derive_bounds(Vec::new(), 1).is_empty());
    }

    #[test]
    fn outcome_fence_total_sums_steps() {
        let o = MigrationOutcome {
            applied: true,
            rolled_back: false,
            steps: vec![
                StepOutcome {
                    desc: "a".into(),
                    fence: Duration::from_millis(3),
                    applied: true,
                },
                StepOutcome {
                    desc: "b".into(),
                    fence: Duration::from_millis(4),
                    applied: true,
                },
            ],
            total: Duration::from_millis(9),
        };
        assert_eq!(o.fence_total(), Duration::from_millis(7));
    }
}

//! Elastic worker scaling: change an operator's parallelism mid-run
//! through the control plane, in one sub-second fenced epoch.
//!
//! The engine fixes each operator's worker count at plan time
//! (`OpSpec.workers`); Reshape (Ch. 3) re-routes tuples *around* a
//! skewed worker but cannot add capacity. This module decouples work
//! allocation from the static plan (the Whiz/F² argument) — the same
//! fenced epoch is also the serving layer's preemption primitive:
//! `crate::service` scales a batch job's operators down to one worker
//! each to hand the freed budget to an interactive tenant, without
//! cancelling the batch job. A
//! [`Command::Scale`](crate::engine::controller::Command) request —
//! from the driver via
//! [`Execution::scale_operator`](crate::engine::Execution::scale_operator)
//! or from the [`AutoscalePlugin`] — runs the following epoch protocol
//! entirely over the existing control plane:
//!
//! ```text
//!           coordinator                    workers
//!               │
//!   (1) FENCE   │── Pause ──────────────▶  all workers
//!               │◀─ PausedAck ──────────   (output flushed: all
//!               │     × every worker        in-flight data parked in
//!               │   bump worker-set epoch   receiver channels/stashes)
//!   (2) UNPLUG  │── ExtractScaleState ──▶  old workers of the target
//!               │◀─ ScaleState ─────────   {operator state + every
//!               │     × old worker set      unprocessed input event +
//!               │                           operator-buffered input +
//!               │                           the live TupleSource on
//!               │                           scan workers}
//!               │   (broadcast-input ops: replicate=true to ONE donor
//!               │    on scale-up — copy, donor keeps everything — or
//!               │    unplug of the RETIRING workers only on
//!               │    scale-down)
//!   (3) RESHAPE │  retire threads (n↓) / spawn threads+mailboxes (n↑),
//!       THE SET │  recompute Range bounds for the new receiver count,
//!               │  repartition surrendered scan ranges over new_n
//!               │  (TupleSource::split stride re-cuts on n↑, chained
//!               │   remainders on n↓ — multiset union preserved)
//!   (4) REHASH  │── InstallState ───────▶  shard s: scope % new_n == w
//!               │   re-route surrendered   (operator-side install_state
//!               │   input through a fresh   merges kind-aware: min/max,
//!               │   partitioner             avg pairs, sorted runs)
//!               │── InstallSource ──────▶  surviving scan workers (the
//!               │                           repartitioned range)
//!               │── InstallReplica ─────▶  scale-spawned workers of a
//!               │   + clone of donor's      broadcast-input op (the
//!               │   pending broadcast       donor's build-side copy)
//!               │   batches
//!   (5) REWIRE  │── RescaleSelf ────────▶  target workers (new peers +
//!               │                           worker-set epoch; a worker
//!               │                           parked in a stale EOF peer
//!               │                           barrier re-enters it)
//!               │── RescaleEdge ────────▶  upstream workers (new
//!               │                           partitioner + senders;
//!               │                           mitigation overlays drop)
//!               │── UpdateUpstreamCount ▶  downstream workers (EOF
//!               │                           accounting)
//!   (6) RESUME  │── FenceResume ────────▶  all workers (skipped if the
//!               │                           driver had paused; undoes
//!               │                           only the fence's pause, so
//!               │                           pre-fence breakpoint parks
//!               │                           survive)
//! ```
//!
//! The fence (steps 1, 2 and 6) is also the substrate for **live plan
//! migration** ([`crate::engine::migrate`]): repartition-scheme swaps,
//! mat insertion/removal and multi-step worker re-plans all run the
//! same FENCE → UNPLUG → … → RESUME epoch, with step 3 replaced by
//! their own plan mutation (protocol diagram in the `migrate` module
//! docs).
//!
//! **Exactness.** Pausing flushes every sender, so the epoch observes a
//! quiescent data plane; the unplug step surrenders *all* state and
//! *all* unprocessed input of the old worker set, so nothing is lost or
//! duplicated; state shards and future tuples are partitioned by the
//! same function (`scope % new_n` / the rebuilt base partitioner), so
//! every key's state and its future input meet on one worker. Sink
//! multisets are therefore identical to an unscaled run.
//!
//! **Sources.** Scan ranges are *splittable*
//! ([`TupleSource::split`](crate::workloads::TupleSource::split)): the
//! built-in generators are stride views over a global id space in which
//! each tuple is a pure function of its id, so the unread remainder of
//! a mid-read worker re-cuts into `n` disjoint deterministic sub-ranges
//! (scale-up) or chains with its siblings' remainders
//! ([`ChainSource`](crate::workloads::ChainSource), scale-down) without
//! changing the emitted multiset or the §2.5/§2.6 replay bytes.
//! Checkpoints embed a [`fork`](crate::workloads::TupleSource::fork) of
//! each live range, so recovery from a checkpoint taken after a source
//! scale re-deploys at the **post-scale** parallelism.
//!
//! **Scatter-merge.** The EOF peer barrier (§3.5.4) is keyed on the
//! fence's worker-set epoch: `PeerEof` carries the epoch of the sibling
//! set it was announced against, receivers count per epoch, and
//! `RescaleSelf` makes a worker parked in a stale barrier re-enter it —
//! re-shipping scattered parts from its re-installed state and
//! re-announcing EOF under the new epoch — so the barrier can neither
//! complete against retired siblings nor wedge on their missing
//! announcements.
//!
//! **Broadcast-input.** Every worker of a broadcast-input operator
//! holds a replica of the broadcast-built state, so scale-up clones one
//! donor — its build-side state
//! ([`Operator::replicate_broadcast_state`](crate::engine::operator::Operator::replicate_broadcast_state))
//! plus its parked broadcast-port input — onto each spawned worker, and
//! scale-down simply drops the retirees' replicas while re-routing
//! their partitioned-port pending (including operator-buffered input
//! such as a join's early probes,
//! [`Operator::drain_buffered_input`](crate::engine::operator::Operator::drain_buffered_input))
//! to the survivors.
//!
//! **EOF accounting.** A worker spawned mid-run can never receive the
//! `End`s that already-completed upstream workers sent to the old
//! receiver set; the coordinator seeds those as `initial_eofs`.
//! Retired workers never send their `End`s; downstream expectations are
//! rewritten from the live worker sets (`UpdateUpstreamCount`).
//!
//! **Refusals.** Operators that already have completed workers (the EOF
//! cascade is under way) and unknown ops / zero or unchanged counts are
//! refused — `scale_operator` returns `Duration::ZERO`. The historical
//! structural refusals (source, scatter-merge, broadcast-input) are
//! gone: all three classes scale through the protocols above.
//!
//! **Ownership.** The coordinator tracks which party — the driver API
//! (tests, Maestro's re-planner) or the [`AutoscalePlugin`] — first
//! successfully scaled each operator, and refuses the other party's
//! later requests for it. Without the guard both policies could
//! interleave conflicting parallelism changes on one operator
//! (last-writer-wins races between Maestro's budgeted assignment and
//! the queue-driven policy).
//!
//! **Maestro integration.** The region scheduler
//! ([`MaestroScheduler`](crate::maestro::MaestroScheduler)) drives this
//! protocol between region activations: with a worker budget
//! configured ([`Config::max_workers`](crate::config::Config)), it
//! re-plans the remaining regions' worker counts from observed
//! statistics and applies the deltas here while those regions' workers
//! are **alive but dormant** — deployed, paused on empty inputs,
//! sources not yet started. Scaling an idle operator exercises the
//! same fence as a mid-stream scale; there is simply no pending input
//! to surrender (scaling a dormant *source* re-cuts its untouched scan
//! range). Operators whose region already drained through pipelined
//! links (and thus completed without an explicit await) are refused by
//! the completed-workers guard, which the scheduler treats as "keep
//! the deploy-time count".
//!
//! **Interactions.** Mitigation overlays are cleared on every scale
//! (their indices and hash bases refer to the old set); Reshape
//! re-detects skew against the new set, and stale `UpdateRoute`s that
//! arrive late are ignored by the partitioner's range guard. The
//! control-replay log does not cover fence messages; recovery
//! re-deploys at the checkpoint's parallelism. Workers spawned mid-run
//! inherit the operator's armed *local* breakpoint; outstanding
//! *global*-breakpoint target assignments are not redistributed — a
//! COUNT/SUM breakpoint armed across a scale keeps its exactness on
//! the old workers' targets but the new workers receive targets only
//! at the next inquiry round. A fence that cannot close (missing pause
//! acks or surrendered states within the deadline) aborts and restores
//! every surrendered state to its owner instead of proceeding.

use crate::engine::controller::{CoordPlugin, PluginCtx};
use crate::engine::message::{WorkerEvent, WorkerId};
use crate::reshape::detector;
use crate::tuple::Value;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Recompute range-partition bounds for a resized receiver set.
///
/// The old bounds are treated as empirical quantile marks: old bound
/// `i` (0-based) sits at fraction `(i+1)/old_n` of the value
/// distribution. New bounds are read off that piecewise-linear CDF at
/// fractions `j/new_n`, clamping at the outermost marks (the engine
/// cannot extrapolate beyond what the plan knew). Non-numeric bounds
/// fall back to nearest-mark selection. Routing stays total for any
/// bounds vector — the last receiver takes everything above the final
/// bound — so a skewed interpolation costs balance, never correctness.
pub fn rescale_bounds(old: &[Value], new_n: usize) -> Vec<Value> {
    if new_n <= 1 || old.is_empty() {
        return Vec::new();
    }
    let m = old.len();
    let numeric: Option<Vec<f64>> = old.iter().map(|v| v.as_float()).collect();
    (1..new_n)
        .map(|j| {
            // Position in old-quantile units, 1.0 = first old bound.
            let p = j as f64 * (m as f64 + 1.0) / new_n as f64;
            let t = (p - 1.0).clamp(0.0, (m - 1) as f64);
            match &numeric {
                Some(xs) => {
                    let i = t.floor() as usize;
                    let f = t - i as f64;
                    let v = if i + 1 < m {
                        xs[i] * (1.0 - f) + xs[i + 1] * f
                    } else {
                        xs[m - 1]
                    };
                    Value::Float(v)
                }
                None => old[(t.round() as usize).min(m - 1)].clone(),
            }
        })
        .collect()
}

/// A simple autoscale policy as a coordinator plugin.
///
/// Reuses the Reshape workload metric (the per-worker unprocessed-queue
/// gauge φ_w, §3.2.1) and the Reshape skew detector: sustained
/// imbalance (the detector finds a skewed worker) or sustained overload
/// (some queue above `autoscale_high_queue`) doubles the operator's
/// workers up to `max`; sustained idleness (total queue below
/// `autoscale_low_queue`) halves them down to `min`. A cooldown after
/// every decision lets the re-hashed state and fresh queues settle
/// before the next reading.
pub struct AutoscalePlugin {
    target_op: usize,
    min_workers: usize,
    max_workers: usize,
    high_ticks: u32,
    idle_ticks: u32,
    cooldown: u32,
    /// Scale decisions taken: (elapsed s, new worker count).
    pub decisions: std::sync::Arc<std::sync::Mutex<Vec<(f64, usize)>>>,
}

impl AutoscalePlugin {
    /// Autoscale `target_op` between `min_workers` and `max_workers`.
    pub fn new(target_op: usize, min_workers: usize, max_workers: usize) -> AutoscalePlugin {
        assert!(min_workers >= 1 && min_workers <= max_workers);
        AutoscalePlugin {
            target_op,
            min_workers,
            max_workers,
            high_ticks: 0,
            idle_ticks: 0,
            cooldown: 0,
            decisions: std::sync::Arc::new(std::sync::Mutex::new(Vec::new())),
        }
    }

    /// Shared handle to the decision log (for harnesses/tests).
    pub fn decisions(&self) -> std::sync::Arc<std::sync::Mutex<Vec<(f64, usize)>>> {
        self.decisions.clone()
    }
}

impl CoordPlugin for AutoscalePlugin {
    fn name(&self) -> &str {
        "autoscale"
    }

    fn period(&self) -> Duration {
        Duration::from_millis(20)
    }

    fn tick(&mut self, ctx: &PluginCtx) {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return;
        }
        let n = ctx.workers_of(self.target_op);
        let mut loads = Vec::with_capacity(n);
        let mut live = 0usize;
        for i in 0..n {
            let id = WorkerId::new(self.target_op, i);
            if ctx.completed.contains(&id) {
                loads.push(0.0);
                continue;
            }
            let Some(g) = ctx.gauges_of(id) else {
                loads.push(0.0);
                continue;
            };
            loads.push(g.queued.load(Ordering::Relaxed).max(0) as f64);
            live += 1;
        }
        if live == 0 {
            return;
        }
        let cfg = ctx.config;
        let max_q = loads.iter().cloned().fold(0.0f64, f64::max);
        let total_q: f64 = loads.iter().sum();
        // Sustained imbalance (the Reshape skew test) or overload.
        let skewed = !detector::detect(
            &loads,
            &[],
            cfg.reshape_eta,
            cfg.reshape_tau,
            1,
        )
        .pairs
        .is_empty();
        if skewed || max_q >= cfg.autoscale_high_queue {
            self.high_ticks += 1;
            self.idle_ticks = 0;
        } else if total_q <= cfg.autoscale_low_queue {
            self.idle_ticks += 1;
            self.high_ticks = 0;
        } else {
            self.high_ticks = 0;
            self.idle_ticks = 0;
        }
        let sustain = cfg.autoscale_sustain_ticks;
        if self.high_ticks >= sustain && n < self.max_workers {
            let target = (n * 2).min(self.max_workers);
            ctx.request_scale(self.target_op, target);
            self.decisions
                .lock()
                .unwrap()
                .push((ctx.started.elapsed().as_secs_f64(), target));
            self.high_ticks = 0;
            self.cooldown = sustain * 2;
        } else if self.idle_ticks >= sustain && n > self.min_workers {
            let target = (n / 2).max(self.min_workers);
            ctx.request_scale(self.target_op, target);
            self.decisions
                .lock()
                .unwrap()
                .push((ctx.started.elapsed().as_secs_f64(), target));
            self.idle_ticks = 0;
            self.cooldown = sustain * 2;
        }
    }

    fn on_event(&mut self, _ev: &WorkerEvent, _ctx: &PluginCtx) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(v: &Value) -> f64 {
        v.as_float().unwrap()
    }

    #[test]
    fn rescale_bounds_doubles_receivers() {
        // 2 receivers (1 bound at the median) → 4 receivers: quartile
        // marks interpolated/clamped around the single known mark.
        let old = vec![Value::Float(50.0)];
        let nb = rescale_bounds(&old, 4);
        assert_eq!(nb.len(), 3);
        // Monotone non-decreasing, centred on the old median.
        assert!(f(&nb[0]) <= f(&nb[1]) && f(&nb[1]) <= f(&nb[2]));
        assert_eq!(f(&nb[1]), 50.0);
    }

    #[test]
    fn rescale_bounds_preserves_marks_on_halving() {
        // 4 receivers → 2: the new median is the old 2nd bound.
        let old = vec![Value::Float(25.0), Value::Float(50.0), Value::Float(75.0)];
        let nb = rescale_bounds(&old, 2);
        assert_eq!(nb.len(), 1);
        assert_eq!(f(&nb[0]), 50.0);
    }

    #[test]
    fn rescale_bounds_monotone_for_any_sizes() {
        let old: Vec<Value> = (1..8).map(|i| Value::Float(i as f64 * 10.0)).collect();
        for n in 2..20 {
            let nb = rescale_bounds(&old, n);
            assert_eq!(nb.len(), n - 1);
            for w in nb.windows(2) {
                assert!(f(&w[0]) <= f(&w[1]));
            }
        }
    }

    #[test]
    fn rescale_bounds_degenerate_cases() {
        assert!(rescale_bounds(&[], 4).is_empty());
        assert!(rescale_bounds(&[Value::Float(1.0)], 1).is_empty());
    }
}

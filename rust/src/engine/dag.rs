//! The logical workflow DAG handed to the engine (and to Maestro).
//!
//! A [`Workflow`] is a DAG of [`OpSpec`]s connected by [`Edge`]s. Each
//! operator declares a *builder* closure producing one [`Operator`]
//! instance per worker (the paper's principal creating its worker
//! actors, §2.3.2), a worker count, and per-input-port partitioning
//! schemes. Edges carry the destination port; whether a port is
//! blocking is a property of the destination operator.

use crate::engine::operator::{Emitter, Operator};
use crate::engine::partitioner::PartitionScheme;
use crate::tuple::{Tuple, TupleBatch};
use crate::workloads::TupleSource;
use std::sync::Arc;

/// Builder producing the operator instance for worker `idx` of `n`.
pub type OpBuilder = Arc<dyn Fn(usize, usize) -> Box<dyn Operator> + Send + Sync>;

/// Builder producing the tuple-source partition for scan worker `idx`
/// of `n`.
pub type SourceBuilder = Arc<dyn Fn(usize, usize) -> Box<dyn TupleSource> + Send + Sync>;

/// Pass-through operator used by plain scans (a scan may instead attach
/// a parser by supplying its own operator builder).
pub struct PassThrough;

impl Operator for PassThrough {
    fn name(&self) -> &str {
        "passthrough"
    }
    fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
        out.emit(t);
    }
    fn process_batch(&mut self, batch: &TupleBatch, _port: usize, out: &mut dyn Emitter) {
        // Forward the shared allocation untouched (zero-copy scan path).
        out.emit_batch(batch.clone());
    }
}

/// One physical operator in the workflow.
#[derive(Clone)]
pub struct OpSpec {
    pub name: String,
    pub workers: usize,
    pub builder: OpBuilder,
    /// For source (scan) operators: the tuple source each worker drives.
    pub source_builder: Option<SourceBuilder>,
    /// Partitioning scheme for each input port (indexed by port).
    pub input_partitioning: Vec<PartitionScheme>,
    /// Ports that are blocking (duplicated from the operator so Maestro
    /// can plan without instantiating workers).
    pub blocking_ports: Vec<usize>,
    /// True for source operators (no input; workers drive generation).
    pub is_source: bool,
    /// Enable the EOF peer barrier for scattered-state merging
    /// (§3.5.4): at EOF every worker ships its foreign runs to their
    /// owners and waits for all siblings before finishing. Set for
    /// mutable-state operators mitigated with SBR (e.g. sort).
    pub scatter_merge: bool,
}

impl OpSpec {
    /// A source (scan) operator: each worker drives one source
    /// partition through a pass-through operator.
    pub fn source(
        name: &str,
        workers: usize,
        sources: impl Fn(usize, usize) -> Box<dyn TupleSource> + Send + Sync + 'static,
    ) -> OpSpec {
        OpSpec {
            name: name.to_string(),
            workers,
            builder: Arc::new(|_, _| Box::new(PassThrough)),
            source_builder: Some(Arc::new(sources)),
            input_partitioning: Vec::new(),
            blocking_ports: Vec::new(),
            is_source: true,
            scatter_merge: false,
        }
    }

    /// A source with a custom per-tuple operator (e.g. a parser).
    pub fn source_with_op(
        name: &str,
        workers: usize,
        sources: impl Fn(usize, usize) -> Box<dyn TupleSource> + Send + Sync + 'static,
        builder: impl Fn(usize, usize) -> Box<dyn Operator> + Send + Sync + 'static,
    ) -> OpSpec {
        OpSpec {
            name: name.to_string(),
            workers,
            builder: Arc::new(builder),
            source_builder: Some(Arc::new(sources)),
            input_partitioning: Vec::new(),
            blocking_ports: Vec::new(),
            is_source: true,
            scatter_merge: false,
        }
    }

    /// A single-input operator.
    pub fn unary(
        name: &str,
        workers: usize,
        scheme: PartitionScheme,
        builder: impl Fn(usize, usize) -> Box<dyn Operator> + Send + Sync + 'static,
    ) -> OpSpec {
        OpSpec {
            name: name.to_string(),
            workers,
            builder: Arc::new(builder),
            source_builder: None,
            input_partitioning: vec![scheme],
            blocking_ports: Vec::new(),
            is_source: false,
            scatter_merge: false,
        }
    }

    /// A two-input operator (e.g. hash join: port 0 = build, blocking;
    /// port 1 = probe).
    pub fn binary(
        name: &str,
        workers: usize,
        schemes: [PartitionScheme; 2],
        blocking_ports: Vec<usize>,
        builder: impl Fn(usize, usize) -> Box<dyn Operator> + Send + Sync + 'static,
    ) -> OpSpec {
        let [s0, s1] = schemes;
        OpSpec {
            name: name.to_string(),
            workers,
            builder: Arc::new(builder),
            source_builder: None,
            input_partitioning: vec![s0, s1],
            blocking_ports,
            is_source: false,
            scatter_merge: false,
        }
    }

    /// Mark ports blocking (builder-style).
    pub fn with_blocking(mut self, ports: Vec<usize>) -> OpSpec {
        self.blocking_ports = ports;
        self
    }

    /// Enable the scattered-state EOF peer barrier (builder-style).
    pub fn with_scatter_merge(mut self) -> OpSpec {
        self.scatter_merge = true;
        self
    }
}

/// A directed edge: output of `from` feeds input port `to_port` of `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    pub to_port: usize,
}

/// The workflow DAG.
#[derive(Clone, Default)]
pub struct Workflow {
    pub ops: Vec<OpSpec>,
    pub edges: Vec<Edge>,
}

impl Workflow {
    pub fn new() -> Workflow {
        Workflow::default()
    }

    /// Add an operator; returns its index.
    pub fn add(&mut self, spec: OpSpec) -> usize {
        self.ops.push(spec);
        self.ops.len() - 1
    }

    /// Connect `from`'s output to `to`'s input port `to_port`.
    pub fn connect(&mut self, from: usize, to: usize, to_port: usize) {
        assert!(from < self.ops.len() && to < self.ops.len());
        assert!(
            to_port < self.ops[to].input_partitioning.len(),
            "operator {} has no input port {to_port}",
            self.ops[to].name
        );
        self.edges.push(Edge { from, to, to_port });
    }

    /// Outgoing edges of an operator.
    pub fn out_edges(&self, op: usize) -> Vec<Edge> {
        self.edges.iter().copied().filter(|e| e.from == op).collect()
    }

    /// Incoming edges of an operator.
    pub fn in_edges(&self, op: usize) -> Vec<Edge> {
        self.edges.iter().copied().filter(|e| e.to == op).collect()
    }

    /// Operators with no outgoing edges (sinks / result operators,
    /// Def. 4.1).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.ops.len())
            .filter(|&i| self.out_edges(i).is_empty())
            .collect()
    }

    /// Operators with no incoming edges.
    pub fn sources(&self) -> Vec<usize> {
        (0..self.ops.len())
            .filter(|&i| self.in_edges(i).is_empty())
            .collect()
    }

    /// Whether an edge lands on a blocking input port of its
    /// destination (Def. 4.2).
    pub fn is_blocking_edge(&self, e: &Edge) -> bool {
        self.ops[e.to].blocking_ports.contains(&e.to_port)
    }

    /// Topological order of operator indices; panics on cycles
    /// (workflows are DAGs by construction).
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.to] += 1;
        }
        let mut queue: Vec<usize> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for e in self.out_edges(i) {
                indeg[e.to] -= 1;
                if indeg[e.to] == 0 {
                    queue.push(e.to);
                }
            }
        }
        assert_eq!(order.len(), n, "workflow graph has a cycle");
        order
    }

    /// Total worker count.
    pub fn total_workers(&self) -> usize {
        self.ops.iter().map(|o| o.workers).sum()
    }

    /// Validate the DAG: every non-source has all input ports
    /// connected, sources have none.
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            let in_edges = self.in_edges(i);
            if op.is_source {
                if !in_edges.is_empty() {
                    return Err(format!("source {} has inputs", op.name));
                }
            } else {
                for port in 0..op.input_partitioning.len() {
                    if !in_edges.iter().any(|e| e.to_port == port) {
                        return Err(format!(
                            "operator {} input port {port} unconnected",
                            op.name
                        ));
                    }
                }
            }
        }
        // Acyclicity.
        let _ = self.topo_order();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::operator::{Emitter, Operator};
    use crate::tuple::Tuple;

    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &str {
            "noop"
        }
        fn process(&mut self, t: Tuple, _port: usize, out: &mut dyn Emitter) {
            out.emit(t);
        }
    }

    fn noop_spec(name: &str, source: bool) -> OpSpec {
        if source {
            OpSpec::source(name, 2, |_, _| {
                Box::new(crate::workloads::VecSource::new(Vec::new()))
            })
        } else {
            OpSpec::unary(name, 2, PartitionScheme::RoundRobin, |_, _| Box::new(Noop))
        }
    }

    #[test]
    fn linear_workflow_valid() {
        let mut w = Workflow::new();
        let a = w.add(noop_spec("scan", true));
        let b = w.add(noop_spec("filter", false));
        w.connect(a, b, 0);
        assert!(w.validate().is_ok());
        assert_eq!(w.sources(), vec![a]);
        assert_eq!(w.sinks(), vec![b]);
    }

    #[test]
    fn unconnected_port_invalid() {
        let mut w = Workflow::new();
        let _a = w.add(noop_spec("scan", true));
        let _b = w.add(noop_spec("filter", false));
        assert!(w.validate().is_err());
    }

    #[test]
    fn topo_order_respects_edges() {
        let mut w = Workflow::new();
        let a = w.add(noop_spec("scan", true));
        let b = w.add(noop_spec("f1", false));
        let c = w.add(noop_spec("f2", false));
        w.connect(a, b, 0);
        w.connect(b, c, 0);
        let order = w.topo_order();
        let pos = |x: usize| order.iter().position(|&y| y == x).unwrap();
        assert!(pos(a) < pos(b) && pos(b) < pos(c));
    }

    #[test]
    #[should_panic(expected = "no input port")]
    fn connect_checks_port_exists() {
        let mut w = Workflow::new();
        let a = w.add(noop_spec("scan", true));
        let b = w.add(noop_spec("filter", false));
        w.connect(a, b, 3);
    }

    #[test]
    fn blocking_edge_detection() {
        let mut w = Workflow::new();
        let a = w.add(noop_spec("scan", true));
        let mut spec = noop_spec("groupby", false);
        spec.blocking_ports = vec![0];
        let b = w.add(spec);
        w.connect(a, b, 0);
        let e = w.edges[0];
        assert!(w.is_blocking_edge(&e));
    }
}

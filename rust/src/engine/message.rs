//! Message types exchanged between actors.
//!
//! Three directions, mirroring Fig. 2.3 of the paper:
//! * worker → worker: [`DataEvent`] (batched tuples, EOF markers,
//!   partitioning-epoch markers, migrated state), carried by the
//!   bounded [`crate::engine::channel::DataRing`] — senders block on a
//!   full ring (congestion control, §2.3.3);
//! * coordinator → worker: [`ControlMessage`] (pause/resume, breakpoint
//!   targets, partitioner updates, operator patches, …);
//! * worker → coordinator: [`WorkerEvent`] (acks, breakpoint reports,
//!   stats, fault-tolerance log records, completion).

use crate::engine::operator::{OpPatch, OpState};
use crate::engine::partitioner::MitigationRoute;
use crate::tuple::{Tuple, TupleBatch};
use crate::workloads::TupleSource;
use std::sync::Arc;
use std::time::Instant;

/// A shared slot carrying one repartitioned [`TupleSource`] to one
/// worker during a source-scale fence. The control plane is `Clone`
/// (broadcast-friendly), boxed sources are not; the slot is cloned as
/// an `Arc` and the receiving worker *takes* the box out.
pub type SourceSlot = Arc<std::sync::Mutex<Option<Box<dyn TupleSource>>>>;

/// Wrap a repartitioned source for [`ControlMessage::InstallSource`].
pub fn source_slot(src: Box<dyn TupleSource>) -> SourceSlot {
    Arc::new(std::sync::Mutex::new(Some(src)))
}

/// Identifies a worker: (operator index in the DAG, worker index within
/// the operator).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkerId {
    pub op: usize,
    pub idx: usize,
}

impl WorkerId {
    pub fn new(op: usize, idx: usize) -> WorkerId {
        WorkerId { op, idx }
    }
}

impl std::fmt::Display for WorkerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "w{}.{}", self.op, self.idx)
    }
}

/// The sender's memoized hash column, shipped alongside a batch so the
/// receiver never re-hashes the key field: SBK gauges count shipped
/// hashes directly, and keyed operators
/// ([`crate::engine::operator::Operator::process_batch_hashed`]) probe
/// with them. `key` names the field the hashes were computed over —
/// receivers whose key field differs simply ignore the column.
///
/// The hashes are `Arc`-shared (fan-out clones copy a pointer) and the
/// column carries its own `offset` so it stays aligned with
/// `batch.slice_from(idx)` when a partially processed message is
/// re-stashed or snapshotted: advancing the batch advances the column.
#[derive(Clone, Debug)]
pub struct HashColumn {
    /// Field index the hashes were computed over.
    pub key: usize,
    hashes: Arc<[u64]>,
    offset: usize,
}

impl HashColumn {
    /// Wrap a finished hash column.
    pub fn new(key: usize, hashes: Arc<[u64]>) -> HashColumn {
        HashColumn { key, hashes, offset: 0 }
    }

    /// Drop the first `n` hashes from the view — mirror of
    /// `TupleBatch::slice_from(n)` on the batch this column rides with.
    pub fn advance(&mut self, n: usize) {
        self.offset += n;
    }

    /// The hashes for view rows `[start, end)`.
    pub fn range(&self, start: usize, end: usize) -> &[u64] {
        &self.hashes[self.offset + start..self.offset + end]
    }

    /// Remaining hashes in the view.
    pub fn len(&self) -> usize {
        self.hashes.len() - self.offset
    }

    /// Whether the view is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A batch of tuples on an edge. `seq` is the per-(sender, receiver)
/// sequence number used for FIFO/exactly-once accounting and the
/// fault-tolerance control-replay log (§2.6.2).
///
/// The payload is a shared [`TupleBatch`]: cloning the message (fan-out
/// edges, snapshots of a partially processed batch) copies an `Arc`,
/// never the tuples. `hashes`, when present, is the sender's memoized
/// key-hash column for the batch (same length as the batch view).
#[derive(Clone, Debug)]
pub struct DataMessage {
    pub from: WorkerId,
    pub port: usize,
    pub seq: u64,
    pub batch: TupleBatch,
    pub hashes: Option<HashColumn>,
}

/// Everything that travels on the data plane.
#[derive(Clone, Debug)]
pub enum DataEvent {
    /// A batch of tuples.
    Batch(DataMessage),
    /// Sender finished its stream for `port` (each receiver counts EOFs
    /// against the number of upstream senders on that port).
    End { from: WorkerId, port: usize },
    /// Partitioning-epoch marker (§3.5.3): the sender switched to
    /// partitioning epoch `epoch`; receivers use it to synchronize
    /// mutable-state migration.
    Marker { from: WorkerId, port: usize, epoch: u64 },
    /// Operator state migrated from a skewed worker to a helper
    /// (Reshape state transfer, §3.2.2 step (c)).
    State { from: WorkerId, state: OpState, transfer_id: u64 },
    /// Peer-barrier marker for the scattered-state merge (§3.5.4): a
    /// sibling worker has shipped all its foreign runs (Fig. 3.11(e)).
    /// `epoch` is the worker-set version stamped by the last scale
    /// fence (0 = the deploy-time set): receivers count PeerEofs per
    /// epoch, so a barrier announced against a retired sibling set can
    /// never satisfy — or deadlock — the rebuilt one.
    PeerEof { from: WorkerId, epoch: u64 },
}

/// A local conditional-breakpoint predicate over output tuples
/// (evaluated independently by each worker, §2.5.2). `Arc` so a single
/// predicate can be broadcast to all workers of an operator.
pub type LocalPredicate = Arc<dyn Fn(&Tuple) -> bool + Send + Sync>;

/// Global-breakpoint target assigned to one worker (§2.5.3): pause and
/// report after producing `amount` more (COUNT: tuples; SUM: field sum).
#[derive(Clone, Debug)]
pub struct BreakpointTarget {
    /// Breakpoint id (several can be active).
    pub id: u64,
    /// COUNT target in tuples, or SUM target in field units.
    pub amount: f64,
    /// For SUM: index of the summed field; None = COUNT.
    pub sum_field: Option<usize>,
}

/// Control-plane messages (coordinator → worker). Kept `Clone` so the
/// coordinator can broadcast one message to all workers of an operator.
#[derive(Clone)]
pub enum ControlMessage {
    /// Stop data processing; ack with `PausedAck` (§2.4.3).
    Pause,
    /// Continue data processing (§2.4.4).
    Resume,
    /// Report current statistics without pausing.
    QueryStats,
    /// Install/replace a local conditional breakpoint on output tuples.
    SetLocalBreakpoint(Option<LocalPredicate>),
    /// Assign a global-breakpoint target (§2.5.3). Worker resets its
    /// produced-counter for this breakpoint and resumes if paused by it.
    AssignTarget(BreakpointTarget),
    /// "How far along are you?" for breakpoint `id`: pause self and
    /// report produced amount since the last `AssignTarget` (time t2/t6
    /// in Fig. 2.5).
    Inquire { id: u64 },
    /// Patch the operator's runtime-modifiable parameters (§2.4.4:
    /// "modify an operator, such as the constant in a selection
    /// predicate").
    ModifyOperator(OpPatch),
    /// Install a mitigation route (Reshape partitioner change) on this
    /// worker's *output* partitioner for operator `target_op`.
    UpdateRoute { target_op: usize, route: MitigationRoute },
    /// Extract the operator state for `keys`/all and send it to `to`
    /// with `transfer_id` (Reshape state migration).
    SendState { to: WorkerId, keys: Option<Vec<u64>>, transfer_id: u64, replicate: bool },
    /// Take a state snapshot for checkpointing; reply `Snapshot`.
    /// Must be sent while paused (quiesced checkpoint).
    TakeSnapshot,
    /// Fault-injection: die immediately without acking (simulated crash,
    /// §2.7.8).
    Die,
    /// Begin source emission (Maestro region activation): scan workers
    /// are deployed dormant and start producing when told (§4.3).
    StartSource,
    /// Fault-tolerance replay (§2.6.2): re-apply these logged control
    /// messages at their recorded data positions during recomputation.
    ReplayLog(Vec<crate::engine::fault::LogRecord>),

    // ---- elastic scaling (engine::scale) ----
    /// Scale fence step (b): unplug. With `replicate: false` the worker
    /// hands the coordinator its full operator state plus all
    /// unprocessed input (stash, queued channel contents, the remainder
    /// of a partially processed batch, any operator-buffered input, and
    /// — on source workers — the live [`crate::workloads::TupleSource`]
    /// itself), replying with [`WorkerEvent::ScaleState`] and ending up
    /// stateless/input-less. With `replicate: true` (broadcast-input
    /// scale-up donor) the worker replies with a **copy** — the
    /// broadcast-side state replica
    /// ([`crate::engine::operator::Operator::replicate_broadcast_state`])
    /// and a clone of its pending input — and keeps everything. Sent
    /// only while the worker is fence-paused, so its input channel is
    /// quiescent.
    ///
    /// `partitioned_only` (broadcast-input scale-down retiree): the
    /// surrendered state is
    /// [`crate::engine::operator::Operator::partitioned_state`] — only
    /// the keyed, partitioned-port-derived part, excluding the
    /// broadcast replica every survivor already holds — so mixed-port
    /// operators with keyed non-broadcast state lose nothing when a
    /// replica holder retires.
    ///
    /// `preserve_routing` (plan migration, `engine::migrate`): the
    /// coordinator promises the surrendered input will be re-injected
    /// into the *same* worker set under unchanged routing (a
    /// repartition fence keeps `n` constant). A single-worker target
    /// uses the promise to remap pending control-replay positions
    /// across the fence's batch consolidation (see
    /// `engine/worker.rs::remap_replay_positions`).
    ExtractScaleState { replicate: bool, partitioned_only: bool, preserve_routing: bool },
    /// Scale fence step (d): install a re-hashed shard of the combined
    /// operator state ([`crate::engine::operator::Operator::install_state`]).
    InstallState(OpState),
    /// Scale fence step (d), broadcast-input scale-up: install the
    /// donor's broadcast-side replica on a freshly spawned worker
    /// ([`crate::engine::operator::Operator::install_replica`]).
    InstallReplica(OpState),
    /// Scale fence step (d), source operators: install a repartitioned
    /// scan range on a surviving worker (the first handler takes the
    /// box out of the shared slot).
    InstallSource(SourceSlot),
    /// Scale fence step (e), sent to workers of the *scaled* operator:
    /// replace the sibling sender set (state-migration peers), tell
    /// the operator its new parallelism
    /// ([`crate::engine::operator::Operator::rescale`]), and stamp the
    /// new worker-set version `epoch` (the scatter-merge EOF peer
    /// barrier is keyed on it — a worker parked in a stale barrier
    /// re-enters it against the new sibling set).
    RescaleSelf {
        peers: Vec<crate::engine::channel::DataSender>,
        workers: usize,
        epoch: u64,
    },
    /// Scale fence step (e), sent to workers of *upstream* operators:
    /// rebuild every output edge targeting `target_op` — new receiver
    /// count, fresh partitioner from `port_schemes[edge.port]` (range
    /// bounds already recomputed by the coordinator; any mitigation
    /// overlay is dropped — Reshape re-detects against the new worker
    /// set), and the new destination sender set.
    RescaleEdge {
        target_op: usize,
        receivers: usize,
        /// Input-partitioning scheme per destination port.
        port_schemes: Vec<crate::engine::partitioner::PartitionScheme>,
        senders: Vec<crate::engine::channel::DataSender>,
    },
    /// Scale fence step (f), sent to workers of *downstream* operators:
    /// the number of upstream senders on `port` changed, so EOF
    /// accounting must expect `count` `End` events instead.
    UpdateUpstreamCount { port: usize, count: usize },
    /// Plan-migration fence (`engine::migrate`), materialization
    /// insertion/removal: retarget this worker's output edge
    /// `(old_target, old_port)` to `(new_target, new_port)` — flush it,
    /// then rebuild it with a fresh partitioner over `scheme` ×
    /// `receivers` and the new destination sender set. Unlike
    /// [`ControlMessage::RescaleEdge`] the *destination operator*
    /// changes, not just its worker set: the edge u→v becomes
    /// u→writer (mat insert) or u→writer reverts to u→v (mat remove).
    RetargetEdge {
        old_target: usize,
        old_port: usize,
        new_target: usize,
        new_port: usize,
        receivers: usize,
        scheme: crate::engine::partitioner::PartitionScheme,
        senders: Vec<crate::engine::channel::DataSender>,
    },
    /// Close of a scale fence: undo the fence's `Pause` only. Unlike
    /// [`ControlMessage::Resume`] it clears just the user/coordinator
    /// pause cause, so a worker that was already parked at a local
    /// breakpoint or a global-breakpoint target before the fence stays
    /// parked afterwards.
    FenceResume,
}

impl std::fmt::Debug for ControlMessage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            ControlMessage::Pause => "Pause",
            ControlMessage::Resume => "Resume",
            ControlMessage::QueryStats => "QueryStats",
            ControlMessage::SetLocalBreakpoint(_) => "SetLocalBreakpoint",
            ControlMessage::AssignTarget(_) => "AssignTarget",
            ControlMessage::Inquire { .. } => "Inquire",
            ControlMessage::ModifyOperator(_) => "ModifyOperator",
            ControlMessage::UpdateRoute { .. } => "UpdateRoute",
            ControlMessage::SendState { .. } => "SendState",
            ControlMessage::TakeSnapshot => "TakeSnapshot",
            ControlMessage::Die => "Die",
            ControlMessage::StartSource => "StartSource",
            ControlMessage::ReplayLog(_) => "ReplayLog",
            ControlMessage::ExtractScaleState { .. } => "ExtractScaleState",
            ControlMessage::InstallState(_) => "InstallState",
            ControlMessage::InstallReplica(_) => "InstallReplica",
            ControlMessage::InstallSource(_) => "InstallSource",
            ControlMessage::RescaleSelf { .. } => "RescaleSelf",
            ControlMessage::RescaleEdge { .. } => "RescaleEdge",
            ControlMessage::UpdateUpstreamCount { .. } => "UpdateUpstreamCount",
            ControlMessage::RetargetEdge { .. } => "RetargetEdge",
            ControlMessage::FenceResume => "FenceResume",
        };
        write!(f, "{name}")
    }
}

/// Per-worker statistics snapshot (what "investigating operators"
/// returns, §2.2.1).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub processed: u64,
    pub produced: u64,
    pub queued: i64,
    pub state_tuples: u64,
    /// Nanoseconds this worker has spent processing tuples (the
    /// Flink-style busy-time base, §3.7.12). Maestro's re-planner folds
    /// this into per-operator `tuple_cost` calibration when a region
    /// completes (`busy_ns / processed`, converted to µs/tuple), so
    /// later regions are priced from measured cost instead of the
    /// configured default.
    pub busy_ns: u64,
}

/// Worker → coordinator events.
pub enum WorkerEvent {
    /// Ack of a `Pause` (or self-pause on breakpoint); carries the
    /// position info the FT log needs (§2.6.2 step iii).
    PausedAck { worker: WorkerId, stats: WorkerStats },
    /// Ack of `Resume`.
    ResumedAck { worker: WorkerId },
    /// Reply to `QueryStats`.
    Stats { worker: WorkerId, stats: WorkerStats },
    /// A local breakpoint predicate matched `tuple` (worker paused
    /// itself first, §2.5.2).
    LocalBreakpointHit { worker: WorkerId, tuple: Tuple },
    /// Worker reached its assigned global-breakpoint target and paused
    /// itself (t1/t5/t9 in Fig. 2.5).
    TargetReached { worker: WorkerId, id: u64, produced: f64 },
    /// Reply to `Inquire`: produced amount since last assignment
    /// (worker paused itself, t3/t7 in Fig. 2.5).
    InquiryReport { worker: WorkerId, id: u64, produced: f64 },
    /// Reply to `TakeSnapshot`.
    Snapshot { worker: WorkerId, snap: crate::engine::fault::WorkerSnapshot },
    /// State-transfer `transfer_id` fully applied at the helper
    /// (Fig. 3.2(d) ack).
    StateApplied { worker: WorkerId, transfer_id: u64 },
    /// A blocking input port finished (all upstream EOFs seen) — Maestro
    /// uses this for region-completion tracking.
    PortCompleted { worker: WorkerId, port: usize },
    /// All upstream senders emitted the epoch marker — safe point for
    /// mutable-state migration (§3.5.3).
    MarkerAligned { worker: WorkerId, epoch: u64 },
    /// Worker finished all input and emitted EOF downstream.
    Completed { worker: WorkerId, stats: WorkerStats },
    /// FT log record for a control message handled mid-stream (§2.6.2).
    Log(crate::engine::fault::LogRecord),
    /// The worker produced its first output tuple (first-response-time
    /// instrumentation for Maestro experiments, §4.5.3).
    FirstOutput { worker: WorkerId, at: Instant },
    /// The worker's DP loop panicked. Sent by the `catch_unwind`
    /// containment wrapper around the worker thread (never by the DP
    /// loop itself), carrying the downcast panic payload and the panic
    /// instant so the coordinator can measure detection latency before
    /// starting supervised recovery.
    WorkerFailed { worker: WorkerId, cause: String, at: Instant },
    /// Coordinator-injected drain marker, never sent by workers. During
    /// supervised recovery the coordinator joins the old worker
    /// generation, then pushes one of these through the (FIFO) event
    /// channel and discards every event ahead of it — anything the dead
    /// generation sent before dying — so stale `Completed`/`Log` events
    /// cannot pollute the rebuilt generation's bookkeeping.
    EpochMark { token: u64 },
    /// Reply to [`ControlMessage::ExtractScaleState`]: the worker's
    /// operator state and unprocessed input events — surrendered
    /// (`replicate: false`, plus the live `TupleSource` on scan
    /// workers) or copied (`replicate: true`, broadcast-build donor;
    /// `source` is then `None`) — for re-hashing/re-routing/replication
    /// across the new worker set (engine::scale fence step (c)).
    ScaleState {
        worker: WorkerId,
        state: OpState,
        pending: Vec<DataEvent>,
        source: Option<Box<dyn TupleSource>>,
    },
}

// Manual: `Box<dyn TupleSource>` (in `ScaleState`) has no `Debug`;
// variant names are all diagnostics ever needed here.
impl std::fmt::Debug for WorkerEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            WorkerEvent::PausedAck { .. } => "PausedAck",
            WorkerEvent::ResumedAck { .. } => "ResumedAck",
            WorkerEvent::Stats { .. } => "Stats",
            WorkerEvent::LocalBreakpointHit { .. } => "LocalBreakpointHit",
            WorkerEvent::TargetReached { .. } => "TargetReached",
            WorkerEvent::InquiryReport { .. } => "InquiryReport",
            WorkerEvent::Snapshot { .. } => "Snapshot",
            WorkerEvent::StateApplied { .. } => "StateApplied",
            WorkerEvent::PortCompleted { .. } => "PortCompleted",
            WorkerEvent::MarkerAligned { .. } => "MarkerAligned",
            WorkerEvent::Completed { .. } => "Completed",
            WorkerEvent::Log(_) => "Log",
            WorkerEvent::FirstOutput { .. } => "FirstOutput",
            WorkerEvent::WorkerFailed { .. } => "WorkerFailed",
            WorkerEvent::EpochMark { .. } => "EpochMark",
            WorkerEvent::ScaleState { .. } => "ScaleState",
        };
        write!(f, "{name}")
    }
}
